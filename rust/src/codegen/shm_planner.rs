//! Shared-memory planning — §5.1.
//!
//! On-chip shared memory is the intermediary that lets ops in one fused
//! kernel keep *different* parallel-loop emitters (block composition).
//! Planning proceeds in the paper's three steps:
//!
//! 1. **Size-requirements analysis** (§5.1.1) — find ops needing a
//!    per-block buffer: interior `Reduce`/`BatchDot` results
//!    (mandatory), expensive elementwise ops with multiple users, and
//!    expensive elementwise ops transitively consumed by a `BatchDot`
//!    (high reuse);
//! 2. **Size shrinking** (§5.1.2) — when the total exceeds the kernel
//!    budget, trade space for recomputation, dropping buffers from
//!    cheapest-to-recompute to dearest, preferring the candidate closest
//!    to the root of the span;
//! 3. **Space sharing** (§5.1.3) — reuse dead buffers along the data
//!    flow, allowed when the new owner *dominates* the previous one in
//!    the dominance tree rooted at the fusion root.

use crate::analysis::{DominatorTree, SpanAnalysis};
use crate::gpusim::DeviceConfig;
use crate::hlo::{Computation, InstrId, Opcode};
use crate::schedule::{OpSchedule, TunedPlan};
use std::collections::{BTreeMap, HashSet};

/// One allocated shared-memory buffer.
#[derive(Debug, Clone, PartialEq)]
pub struct ShmSlot {
    /// Byte offset inside the kernel's shared-memory segment.
    pub offset: usize,
    /// Buffer size in bytes (the owner's per-block chunk).
    pub bytes: usize,
    /// `Some(prev)` when this op reuses the buffer first allocated for
    /// `prev` (the paper's SHARE annotation); `None` for fresh ALLOCs.
    pub reused_from: Option<InstrId>,
}

/// The shared-memory plan for one fused kernel.
#[derive(Debug, Clone, Default)]
pub struct ShmPlan {
    /// Per-op buffer assignment (ALLOC and SHARE entries).
    pub slots: BTreeMap<InstrId, ShmSlot>,
    /// Total distinct bytes allocated (peak shared-memory usage).
    pub total_bytes: usize,
    /// Ops whose buffers were dropped to recomputation by shrinking.
    pub shrunk: Vec<InstrId>,
    /// Bytes of allocated space reused by at least one later op — the
    /// numerator of Table 3's Shared Ratio.
    pub shared_bytes: usize,
    /// Mandatory buffers that cannot fit even alone: the third
    /// stitching tier materializes them in grid-visible global memory
    /// (arena regions) with a grid-wide fence between producer and
    /// consumer phases. Always empty for plans produced by
    /// [`plan_shared_memory`]; filled by [`plan_shared_memory_spill`].
    pub spilled: Vec<InstrId>,
}

impl ShmPlan {
    /// Whether the §5.1.2 shrinking process fired for this kernel
    /// (Table 3's #Shrink counts kernels where it did).
    pub fn shrink_triggered(&self) -> bool {
        !self.shrunk.is_empty()
    }

    /// Table 3's Shared Ratio for this kernel.
    pub fn shared_ratio(&self) -> f64 {
        if self.total_bytes == 0 {
            0.0
        } else {
            self.shared_bytes as f64 / self.total_bytes as f64
        }
    }
}

/// Planning failure: requirements exceed the budget even after
/// shrinking — fed back to fusion (§5.1.2).
#[derive(Debug, Clone, PartialEq)]
pub enum ShmError {
    Exceeded { required: usize, limit: usize },
}

/// Candidate priority classes, in *drop order* (§5.1.2: "we start from
/// inexpensive elementwise ops with multiple users, then expensive
/// elementwise ops with multiple uses, finally expensive ops with
/// transitive uses by BatchMatMul").
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Class {
    CheapMultiUser = 0,
    ExpensiveMultiUser = 1,
    ExpensiveFeedsDot = 2,
    /// Interior reduce/batch-dot results: structurally required, never
    /// dropped.
    Mandatory = 3,
}

/// Plan shared memory for the fused group under `tuned`.
pub fn plan_shared_memory(
    comp: &Computation,
    members: &HashSet<InstrId>,
    roots: &[InstrId],
    tuned: &TunedPlan,
    dev: &DeviceConfig,
) -> Result<ShmPlan, ShmError> {
    plan_impl(comp, members, roots, tuned, dev, false)
}

/// Plan shared memory with the global-memory fallback tier enabled:
/// where [`plan_shared_memory`] would fail with [`ShmError::Exceeded`],
/// the mandatory buffers that overflow the budget are moved into the
/// plan's `spilled` set (largest chunk first) until the rest fits.
/// Never fails — every group is representable once spilling is allowed.
pub fn plan_shared_memory_spill(
    comp: &Computation,
    members: &HashSet<InstrId>,
    roots: &[InstrId],
    tuned: &TunedPlan,
    dev: &DeviceConfig,
) -> ShmPlan {
    match plan_impl(comp, members, roots, tuned, dev, true) {
        Ok(plan) => plan,
        Err(ShmError::Exceeded { .. }) => unreachable!("spill planning never fails"),
    }
}

fn plan_impl(
    comp: &Computation,
    members: &HashSet<InstrId>,
    roots: &[InstrId],
    tuned: &TunedPlan,
    dev: &DeviceConfig,
    spill: bool,
) -> Result<ShmPlan, ShmError> {
    let root_set: HashSet<InstrId> = roots.iter().copied().collect();
    let mut candidates: Vec<(InstrId, Class, usize)> = Vec::new(); // (id, class, bytes)

    for &id in members {
        if root_set.contains(&id) {
            continue; // roots write global memory directly
        }
        let Some(OpSchedule::Scheduled(sched)) = tuned.assignment.get(&id).copied() else {
            continue; // inlined ops are never materialized
        };
        let instr = comp.get(id);
        let chunk_bytes =
            sched.chunk_elements(&instr.shape) as usize * instr.shape.dtype.byte_size();
        let in_group_users =
            comp.users(id).iter().filter(|u| members.contains(u)).count();

        let class = if instr.opcode.is_reduce() || instr.opcode == Opcode::BatchDot {
            Some(Class::Mandatory)
        } else if instr.opcode.is_expensive_elementwise() {
            if feeds_batch_dot(comp, id, members) {
                Some(Class::ExpensiveFeedsDot)
            } else if in_group_users > 1 {
                Some(Class::ExpensiveMultiUser)
            } else {
                None
            }
        } else if instr.opcode.is_elementwise() && in_group_users > 1 {
            Some(Class::CheapMultiUser)
        } else {
            None
        };
        if let Some(c) = class {
            candidates.push((id, c, chunk_bytes));
        }
    }

    // Emission order = ascending id (construction order is topological).
    candidates.sort_by_key(|&(id, _, _)| id);

    // Dominance tree for the sharing rule; only single-root groups have a
    // well-defined root to anchor it (multi-root elementwise groups have
    // no interior buffers in practice).
    let domtree = if roots.len() == 1 {
        Some(DominatorTree::build(comp, roots[0], Some(members)))
    } else {
        None
    };
    let spans = SpanAnalysis::run(comp);
    let limit = dev.shared_mem_kernel_limit;

    let mut dropped: Vec<InstrId> = Vec::new();
    let mut spilled: Vec<InstrId> = Vec::new();
    loop {
        let live: Vec<(InstrId, Class, usize)> = candidates
            .iter()
            .copied()
            .filter(|(id, _, _)| !dropped.contains(id) && !spilled.contains(id))
            .collect();
        let mut plan = allocate(comp, members, &live, domtree.as_ref(), &dropped);
        if plan.total_bytes <= limit {
            plan.spilled = spilled;
            return Ok(plan);
        }
        // §5.1.2 shrinking: drop the lowest class first; within a class,
        // prefer the candidate closest to the root of the span.
        let victim = live
            .iter()
            .filter(|(_, c, _)| *c != Class::Mandatory)
            .min_by_key(|(id, c, _)| (*c, spans.span_of(*id)))
            .map(|(id, _, _)| *id);
        match victim {
            Some(v) => dropped.push(v),
            None if spill => {
                // Third tier: every remaining candidate is Mandatory,
                // so move the largest chunk to a global-memory region
                // (ties break to the earliest op) and retry the rest.
                let v = live
                    .iter()
                    .max_by_key(|(id, _, bytes)| (*bytes, std::cmp::Reverse(*id)))
                    .map(|(id, _, _)| *id)
                    .expect("overflow with no live candidates");
                spilled.push(v);
            }
            None => return Err(ShmError::Exceeded { required: plan.total_bytes, limit }),
        }
    }
}

/// Linear-scan allocation with dominance-gated reuse.
fn allocate(
    comp: &Computation,
    members: &HashSet<InstrId>,
    live: &[(InstrId, Class, usize)],
    domtree: Option<&DominatorTree>,
    dropped: &[InstrId],
) -> ShmPlan {
    // Free point of a buffer: after its last in-group user is emitted.
    let last_use = |id: InstrId| -> usize {
        comp.users(id)
            .iter()
            .filter(|u| members.contains(u))
            .map(|u| u.0)
            .max()
            .unwrap_or(id.0)
    };

    struct Region {
        owner: InstrId,
        offset: usize,
        bytes: usize,
        free_after: usize,
        reused: bool,
    }
    let mut regions: Vec<Region> = Vec::new();
    let mut plan = ShmPlan { shrunk: dropped.to_vec(), ..Default::default() };
    let mut cursor = 0usize; // next fresh offset

    for &(id, _, bytes) in live {
        let emit_idx = id.0;
        // Find a dead region big enough whose owner this op dominates
        // (§5.1.3's rule: Reduce.2 reuses Reduce.1 because it dominates
        // it). An elementwise op that is itself the buffer's last reader
        // may overwrite it in place (Figure 3: Divide.1 reuses
        // Exponential.1 while consuming it).
        let is_ew = comp.get(id).opcode.is_elementwise();
        let reuse = regions.iter_mut().find(|r| {
            (r.free_after < emit_idx || (r.free_after == emit_idx && is_ew))
                && r.bytes >= bytes
                && domtree.map(|t| t.dominates(id, r.owner)).unwrap_or(false)
        });
        match reuse {
            Some(r) => {
                plan.slots.insert(
                    id,
                    ShmSlot { offset: r.offset, bytes, reused_from: Some(r.owner) },
                );
                plan.shared_bytes += bytes;
                r.owner = id;
                r.free_after = last_use(id);
                r.reused = true;
            }
            None => {
                plan.slots.insert(id, ShmSlot { offset: cursor, bytes, reused_from: None });
                regions.push(Region {
                    owner: id,
                    offset: cursor,
                    bytes,
                    free_after: last_use(id),
                    reused: false,
                });
                cursor += bytes;
            }
        }
    }
    plan.total_bytes = cursor;
    plan
}

/// Does `id`'s value flow into a `BatchDot` within the group, possibly
/// through shape-modulation ops (the Figure 3 `Divide.1 → Bitcast.1 →
/// Dot.1` pattern)?
fn feeds_batch_dot(comp: &Computation, id: InstrId, members: &HashSet<InstrId>) -> bool {
    let mut stack: Vec<InstrId> = comp.users(id).iter().copied().collect();
    let mut seen: HashSet<InstrId> = HashSet::new();
    while let Some(u) = stack.pop() {
        if !members.contains(&u) || !seen.insert(u) {
            continue;
        }
        let op = comp.get(u).opcode;
        if op == Opcode::BatchDot {
            return true;
        }
        if op.is_shape_modulation() {
            stack.extend(comp.users(u).iter().copied());
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::instruction::ReduceKind;
    use crate::hlo::{GraphBuilder, Shape};
    use crate::schedule::{tune, PerfLibrary, TuningConfig};

    /// Figure 3's full pattern: softmax stitched into a batch-dot.
    /// Expected (per the paper's annotations): both reduces ALLOC,
    /// exp ALLOCs, divide SHAREs exp's buffer, the second reduce SHAREs
    /// the first's.
    fn fig3() -> (Computation, Vec<InstrId>, InstrId) {
        let mut b = GraphBuilder::new("fig3");
        let scores = b.param("scores", Shape::f32(&[8, 64, 64]));
        let v = b.param("v", Shape::f32(&[8, 64, 32]));
        let m = b.reduce(scores, &[2], ReduceKind::Max); // Reduce.1
        let mb = b.broadcast(m, &[8, 64, 64], &[0, 1]);
        let sh = b.sub(scores, mb);
        let e = b.exp(sh); // Exponential.1
        let s = b.reduce(e, &[2], ReduceKind::Sum); // Reduce.2
        let sb = b.broadcast(s, &[8, 64, 64], &[0, 1]);
        let p = b.div(e, sb); // Divide.1
        let bc = b.bitcast(p, &[8, 64, 64]); // Bitcast.1
        let out = b.batch_dot(bc, v); // Dot.1
        let comp = b.finish(out);
        (comp, vec![m, mb, sh, e, s, sb, p, bc], out)
    }

    fn plan_fig3() -> (Computation, Vec<InstrId>, InstrId, ShmPlan) {
        let (comp, ids, out) = fig3();
        let mut members: HashSet<InstrId> = ids.iter().copied().collect();
        members.insert(out);
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let tuned = tune(&comp, &members, &[out], &mut lib, &TuningConfig::default())
            .expect("fig3 must tune");
        let plan =
            plan_shared_memory(&comp, &members, &[out], &tuned, &DeviceConfig::pascal())
                .expect("fig3 must fit");
        (comp, ids, out, plan)
    }

    #[test]
    fn figure3_allocations_match_paper() {
        let (_, ids, _, plan) = plan_fig3();
        let (m, e, s, p) = (ids[0], ids[3], ids[4], ids[6]);
        // Reduce.1, Exponential.1 get fresh ALLOCs.
        assert!(plan.slots[&m].reused_from.is_none(), "Reduce.1 should ALLOC");
        assert!(plan.slots[&e].reused_from.is_none(), "Exponential.1 should ALLOC");
        // Divide.1 SHAREs Exponential.1's buffer in place (the paper's
        // §5.1.3 example). In the stable softmax Reduce.2 does not
        // dominate Reduce.1 (the subtract path bypasses it), so the
        // planner conservatively keeps the second reduce's own buffer.
        assert_eq!(plan.slots[&p].reused_from, Some(e), "Divide.1 should reuse Exponential.1");
        assert!(plan.slots[&s].reused_from.is_none());
        assert!(plan.shared_ratio() > 0.0);
    }

    #[test]
    fn figure3_fits_budget() {
        let (_, _, _, plan) = plan_fig3();
        assert!(plan.total_bytes <= DeviceConfig::pascal().shared_mem_kernel_limit);
        assert!(!plan.shrink_triggered());
    }

    #[test]
    fn single_user_cheap_ops_get_no_buffer() {
        let mut b = GraphBuilder::new("cheap");
        let x = b.param("x", Shape::f32(&[64, 64]));
        let a = b.add(x, x);
        let t = b.tanh(a);
        let comp = b.finish(t);
        let members: HashSet<InstrId> = [a, t].into_iter().collect();
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let tuned = tune(&comp, &members, &[t], &mut lib, &TuningConfig::default()).unwrap();
        let plan =
            plan_shared_memory(&comp, &members, &[t], &tuned, &DeviceConfig::pascal()).unwrap();
        assert!(plan.slots.is_empty());
        assert_eq!(plan.total_bytes, 0);
    }

    #[test]
    fn shrinking_drops_cheap_multiuser_first() {
        // A cheap multi-user op and an expensive multi-user op compete
        // for a budget that fits only one: the cheap one is dropped.
        let mut b = GraphBuilder::new("shrink");
        let dev = DeviceConfig { shared_mem_kernel_limit: 3000, ..DeviceConfig::pascal() };
        let x = b.param("x", Shape::f32(&[16, 512]));
        let a = b.add(x, x); // cheap, two users
        let e = b.exp(a); // expensive, two users
        let t1 = b.tanh(e);
        let t2 = b.sigmoid(e);
        let u = b.add(t1, t2);
        let w = b.mul(u, a);
        let r = b.reduce(w, &[1], ReduceKind::Sum);
        let comp = b.finish(r);
        let members: HashSet<InstrId> = [a, e, t1, t2, u, w, r].into_iter().collect();
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let tuning = TuningConfig::default();
        // Tune under the restricted device budget via a plan that yields
        // 16 blocks → 512-float (2 KB) chunks per buffered op.
        let tuned = tune(&comp, &members, &[r], &mut lib, &tuning).unwrap();
        match plan_shared_memory(&comp, &members, &[r], &tuned, &dev) {
            Ok(plan) => {
                if plan.shrink_triggered() {
                    // cheap `add` dropped before expensive `exp`
                    assert!(plan.shrunk.contains(&a));
                    assert!(!plan.shrunk.contains(&e));
                }
            }
            Err(_) => panic!("droppable candidates must allow shrinking to succeed"),
        }
    }

    #[test]
    fn exceeded_when_mandatory_buffers_overflow() {
        // An interior reduce is a Mandatory buffer (never dropped): with
        // no droppable candidates and a budget below the reduce's chunk,
        // planning must reject the group with ShmError::Exceeded — the
        // feedback signal fusion uses to give up on a candidate.
        let mut b = GraphBuilder::new("exceed");
        let x = b.param("x", Shape::f32(&[4, 4096]));
        let e = b.exp(x); // single user: not a candidate itself
        let r = b.reduce(e, &[1], ReduceKind::Sum); // interior -> Mandatory
        let rb = b.broadcast(r, &[4, 4096], &[0]);
        let y = b.param("y", Shape::f32(&[4, 4096]));
        let o = b.sub(rb, y);
        let comp = b.finish(o);
        let members: HashSet<InstrId> = [e, r, rb, o].into_iter().collect();
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let tuned = tune(&comp, &members, &[o], &mut lib, &TuningConfig::default()).unwrap();
        let tiny = DeviceConfig { shared_mem_kernel_limit: 2, ..DeviceConfig::pascal() };
        match plan_shared_memory(&comp, &members, &[o], &tuned, &tiny) {
            Err(ShmError::Exceeded { required, limit }) => {
                assert_eq!(limit, 2);
                assert!(required > limit, "required {required} must exceed limit {limit}");
            }
            other => panic!("expected ShmError::Exceeded, got {other:?}"),
        }
        // The same group fits a real device.
        assert!(
            plan_shared_memory(&comp, &members, &[o], &tuned, &DeviceConfig::pascal()).is_ok()
        );
    }

    #[test]
    fn spill_planner_moves_mandatory_overflow_to_global_tier() {
        // Same group that exceeded_when_mandatory_buffers_overflow
        // rejects: with the global tier enabled the planner must
        // succeed by spilling the interior reduce instead.
        let mut b = GraphBuilder::new("spill");
        let x = b.param("x", Shape::f32(&[4, 4096]));
        let e = b.exp(x);
        let r = b.reduce(e, &[1], ReduceKind::Sum);
        let rb = b.broadcast(r, &[4, 4096], &[0]);
        let y = b.param("y", Shape::f32(&[4, 4096]));
        let o = b.sub(rb, y);
        let comp = b.finish(o);
        let members: HashSet<InstrId> = [e, r, rb, o].into_iter().collect();
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let tuned = tune(&comp, &members, &[o], &mut lib, &TuningConfig::default()).unwrap();
        let tiny = DeviceConfig { shared_mem_kernel_limit: 2, ..DeviceConfig::pascal() };
        let plan = plan_shared_memory_spill(&comp, &members, &[o], &tuned, &tiny);
        assert!(plan.spilled.contains(&r), "interior reduce must spill");
        assert!(plan.total_bytes <= tiny.shared_mem_kernel_limit);
        assert!(!plan.slots.contains_key(&r), "spilled ops get no shm slot");
        // On a real device the same group fits and nothing spills.
        let fits =
            plan_shared_memory_spill(&comp, &members, &[o], &tuned, &DeviceConfig::pascal());
        assert!(fits.spilled.is_empty());
    }

    #[test]
    fn feeds_batch_dot_through_shape_ops() {
        let (comp, ids, out) = fig3();
        let mut members: HashSet<InstrId> = ids.iter().copied().collect();
        members.insert(out);
        let p = ids[6]; // Divide.1 → Bitcast.1 → Dot.1
        assert!(feeds_batch_dot(&comp, p, &members));
        let m = ids[0]; // Reduce.1 feeds broadcast→sub→…: broadcast is
                        // shape-mod but sub is not → no direct dot path
        assert!(!feeds_batch_dot(&comp, m, &members));
    }
}
