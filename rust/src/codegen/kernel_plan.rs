//! The emitted kernel artifact and its simulator projection.

use super::shm_planner::ShmPlan;
use crate::gpusim::cost::KernelDesc;
use crate::hlo::{Computation, InstrId};
use crate::schedule::{OpSchedule, Schedule, TunedPlan};
use std::collections::HashSet;

/// Which emitter produced an op's code (Algorithm 2's dispatch).
#[derive(Debug, Clone, PartialEq)]
pub enum EmitterKind {
    /// Own parallel loop under the given schedule (`StitchedEmitter`).
    Stitched(Schedule),
    /// Composed into its consumer's loop body (XLA's
    /// `ElementalIrEmitter` fallback).
    Elemental,
}

/// Code-generation record for one op in the fused kernel.
#[derive(Debug, Clone)]
pub struct EmittedOp {
    pub id: InstrId,
    pub emitter: EmitterKind,
    /// Writes its per-block result to shared memory (`EmitWriteSharedArray`).
    pub writes_shared: bool,
    /// Writes to global memory (`EmitWriteOutputArray` — fusion roots).
    pub writes_output: bool,
    /// Writes its result to a grid-visible global-memory spill region
    /// (`EmitWriteSpillArray` — the third stitching tier), followed by a
    /// grid-wide fence before any consumer phase reads it.
    pub writes_spill: bool,
    /// Pseudo-IR lines for this op (inspection/debugging; stands in for
    /// the LLVM IR the paper emits).
    pub ir: Vec<String>,
}

/// A fully planned kernel: what the paper's codegen phase hands to LLVM,
/// minus the actual LLVM — launch dims, shared-memory layout, per-op
/// emitters and pseudo-IR.
#[derive(Debug, Clone)]
pub struct KernelPlan {
    pub name: String,
    /// Launch dimensions.
    pub blocks: u64,
    pub threads: u32,
    /// Shared-memory layout.
    pub shm: ShmPlan,
    /// Per-op emission records, in emission (topological) order.
    pub ops: Vec<EmittedOp>,
    /// Estimated execution time from tuning (sum-of-ops model, §4.4).
    pub est_exec_us: f64,
}

impl KernelPlan {
    /// Render the whole kernel's pseudo-IR.
    pub fn ir_text(&self) -> String {
        let mut out = format!(
            "; kernel {} <<<{}, {}>>> smem={}B\n",
            self.name, self.blocks, self.threads, self.shm.total_bytes
        );
        for op in &self.ops {
            for line in &op.ir {
                out.push_str(line);
                out.push('\n');
            }
        }
        out
    }

    /// Project the fused kernel onto a simulator descriptor.
    pub fn to_kernel_desc(
        &self,
        comp: &Computation,
        members: &HashSet<InstrId>,
        tuned: &TunedPlan,
    ) -> KernelDesc {
        let mut d = fused_kernel_desc(comp, members, tuned);
        d.smem_bytes = self.shm.total_bytes;
        // Spilled intermediates round-trip through DRAM: written once
        // by the producer phase, read back by consumer phases.
        for &id in &self.shm.spilled {
            let bytes = comp.get(id).shape.byte_size() as u64;
            d.bytes_written += bytes;
            d.bytes_read += bytes;
        }
        d
    }
}

/// Resource descriptor of a fused kernel: DRAM traffic is the group's
/// *boundary* footprint (internal values stay on chip — the whole point
/// of stitching, §4.1 objective (1)), flops accumulate over members, and
/// the worst member coalescing gates the memory system.
pub fn fused_kernel_desc(
    comp: &Computation,
    members: &HashSet<InstrId>,
    tuned: &TunedPlan,
) -> KernelDesc {
    let mut inputs: HashSet<InstrId> = HashSet::new();
    let mut bytes_written = 0u64;
    let mut flops = 0u64;
    let mut weighted = 0f64;
    let mut worst_coalescing: f64 = 1.0;
    // deterministic iteration: float accumulation order must not depend
    // on hash state (compilation is asserted reproducible)
    let mut ordered: Vec<InstrId> = members.iter().copied().collect();
    ordered.sort_unstable();
    for id in ordered {
        let instr = comp.get(id);
        for &op in &instr.operands {
            if !members.contains(&op) {
                inputs.insert(op);
            }
        }
        if comp.users(id).iter().any(|u| !members.contains(u)) || comp.users(id).is_empty() {
            bytes_written += instr.shape.byte_size() as u64;
        }
        if let Some(OpSchedule::Scheduled(s)) = tuned.assignment.get(&id) {
            let d = crate::schedule::perf_library::kernel_desc(
                comp,
                id,
                *s,
                tuned.threads,
                &crate::gpusim::DeviceConfig::pascal(),
            );
            flops += d.flops;
            weighted += d.effective_flops();
            worst_coalescing = worst_coalescing.min(d.coalescing);
        }
    }
    let bytes_read: u64 = inputs.iter().map(|&i| comp.get(i).shape.byte_size() as u64).sum();
    let op_weight = if flops > 0 { weighted / flops as f64 } else { 1.0 };
    KernelDesc {
        bytes_read,
        bytes_written,
        flops,
        blocks: tuned.blocks,
        threads: tuned.threads,
        smem_bytes: 0,
        coalescing: worst_coalescing,
        op_weight,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::DeviceConfig;
    use crate::hlo::instruction::ReduceKind;
    use crate::hlo::{GraphBuilder, Shape};
    use crate::schedule::{tune, PerfLibrary, TuningConfig};

    #[test]
    fn fused_desc_counts_boundary_traffic_only() {
        let mut b = GraphBuilder::new("kd");
        let x = b.param("x", Shape::f32(&[64, 64]));
        let e = b.exp(x);
        let r = b.reduce(e, &[1], ReduceKind::Sum);
        let comp = b.finish(r);
        let members: HashSet<InstrId> = [e, r].into_iter().collect();
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let tuned = tune(&comp, &members, &[r], &mut lib, &TuningConfig::default()).unwrap();
        let plan = super::super::emitter::emit_group(
            &comp,
            &members,
            &[r],
            &tuned,
            &DeviceConfig::pascal(),
            "k0",
        )
        .unwrap();
        let desc = plan.to_kernel_desc(&comp, &members, &tuned);
        assert_eq!(desc.bytes_read, 64 * 64 * 4); // x only
        assert_eq!(desc.bytes_written, 64 * 4); // r only — e stays on chip
        assert!(desc.flops > 0);
    }
}
