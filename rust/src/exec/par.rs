//! Block-parallel fan-out for the stitched VM.
//!
//! Thread blocks of one launch are independent by construction — the VM
//! enforces the no-cross-block-synchronization invariant (a block only
//! reads its own shared chunk and its own slice of same-launch
//! outputs), so the grid loop can spread over cores with no
//! coordination beyond the join. The one sanctioned cross-block edge —
//! the global tier's grid fence — is realized by running one fan-out
//! per fence-delimited phase: the join between phases *is* the fence. This module is the rayon-shaped core
//! of that fan-out, implemented on `std::thread::scope` because the
//! offline build image carries no external crates (the repo's only
//! dependency is `anyhow`); swapping a real rayon pool in later only
//! changes this file.
//!
//! Determinism: the partition of blocks over workers is a pure function
//! of `(blocks, workers)`, every block computes its elements
//! identically regardless of which worker runs it, and the per-worker
//! ledgers are folded in worker order — so results and launch ledgers
//! are bit-identical at any thread count.
//!
//! The worker count resolves once per process from `FUSION_VM_THREADS`
//! (CI pins it so bench gates are reproducible) and defaults to the
//! machine's available parallelism. A [`super::machine::ExecArena`] can
//! override it per arena — the serving pool divides cores between
//! workers so N serving shards × T VM threads never oversubscribes.

use std::sync::OnceLock;

/// Process-wide default VM thread count: `FUSION_VM_THREADS` when set
/// (any value `>= 1`), else available parallelism.
pub fn default_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("FUSION_VM_THREADS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
    })
}

/// Run `f(worker_index, &mut scratch[worker_index])` once per scratch
/// slot, concurrently, returning the results in worker order. Slot 0
/// runs on the calling thread (no spawn for the single-worker case);
/// the rest run on scoped threads. Panics in `f` propagate.
pub fn fan_out<S: Send, R: Send>(
    scratch: &mut [S],
    f: impl Fn(usize, &mut S) -> R + Sync,
) -> Vec<R> {
    let n = scratch.len();
    if n == 0 {
        return Vec::new();
    }
    if n == 1 {
        return vec![f(0, &mut scratch[0])];
    }
    std::thread::scope(|sc| {
        let mut iter = scratch.iter_mut().enumerate();
        let (t0, s0) = iter.next().expect("n >= 1");
        let handles: Vec<_> = iter
            .map(|(t, s)| {
                let f = &f;
                sc.spawn(move || f(t, s))
            })
            .collect();
        let mut out = Vec::with_capacity(n);
        out.push(f(t0, s0));
        for h in handles {
            out.push(h.join().expect("VM block worker panicked"));
        }
        out
    })
}

/// Contiguous block range worker `t` of `workers` owns out of `blocks`
/// total: the canonical `[t*B/W, (t+1)*B/W)` split — every block in
/// exactly one range, ranges in ascending block order.
pub fn block_range(blocks: i64, workers: usize, t: usize) -> std::ops::Range<i64> {
    let w = workers.max(1) as i64;
    let t = t as i64;
    (t * blocks / w)..((t + 1) * blocks / w)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_ranges_partition() {
        for blocks in [0i64, 1, 2, 7, 64, 1000] {
            for workers in [1usize, 2, 3, 8, 13] {
                let mut covered = 0i64;
                let mut next = 0i64;
                for t in 0..workers {
                    let r = block_range(blocks, workers, t);
                    assert_eq!(r.start, next, "ranges must be contiguous");
                    assert!(r.end >= r.start);
                    covered += r.end - r.start;
                    next = r.end;
                }
                assert_eq!(covered, blocks, "{blocks} blocks / {workers} workers");
                assert_eq!(next, blocks);
            }
        }
    }

    #[test]
    fn fan_out_runs_every_slot_once() {
        let mut scratch = vec![0u64; 5];
        let out = fan_out(&mut scratch, |t, s| {
            *s += 1;
            t * 10
        });
        assert_eq!(out, vec![0, 10, 20, 30, 40]);
        assert!(scratch.iter().all(|&s| s == 1));
    }

    #[test]
    fn fan_out_empty_and_single() {
        let mut none: Vec<u8> = Vec::new();
        assert!(fan_out(&mut none, |_, _| 1).is_empty());
        let mut one = vec![7u8];
        assert_eq!(fan_out(&mut one, |t, s| (t, *s)), vec![(0, 7)]);
    }

    #[test]
    fn default_threads_is_at_least_one() {
        assert!(default_threads() >= 1);
    }
}
