//! Lowering: `KernelPlan`s → executable stitched bytecode.
//!
//! This is the pass that turns the *plans* produced by §4/§5 of the
//! paper into something that runs. Per fused group it follows exactly
//! the decisions the emitter (Algorithm 2) recorded:
//!
//! - ops the emitter gave their own parallel loop **and** a write
//!   (shared or output) become [`BlockStep::Loop`]s under their tuned
//!   schedule, followed by a [`BlockStep::Barrier`] for shared writes;
//! - elemental (thread-composed) ops are inlined into their consumers'
//!   [`ThreadProg`]s — they have no loop of their own, which is the
//!   whole point of thread composition;
//! - shared-memory operands compile to [`TInstr::LoadShared`] against
//!   the block's region at the planner's offset; out-of-group operands
//!   compile to [`TInstr::LoadGlobal`];
//! - `Reduce`/`BatchDot` get dedicated loop kinds (they have no
//!   single-lane form, mirroring the Table 1 propagation rule).
//!
//! Library-call groups (`Dot`/`Convolution`) lower to
//! [`LibraryCall`]s — separate launches, counted separately by the
//! [`super::LaunchLedger`] like the paper's Fig. 7 excludes them from
//! the generated-kernel ratio.

use super::bytecode::{
    compile_affine, compile_affine_sched, sched_chunk, BlockStep, IndexMap, IndexStep,
    KernelProgram, LoopKind, Reg, ShmRegion, TInstr, ThreadProg, UnOp, WriteTarget, CONST_FILL,
};
use super::machine::{BufRead, Launch, LibKind, LibraryCall, ParamSpec, StitchedExecutable};
use super::memplan;
use crate::codegen::kernel_plan::EmitterKind;
use crate::codegen::KernelPlan;
use crate::fusion::{FusionGroup, FusionPlan, GroupKind};
use crate::hlo::{Computation, InstrId, Module, Opcode};
use crate::schedule::Schedule;
use anyhow::{anyhow, bail};
use std::collections::{BTreeSet, HashMap, HashSet};

/// Lower a compiled module (fusion plan + emitted kernel plans) into a
/// [`StitchedExecutable`]: one launch per fused group, topologically
/// ordered, plus one launch per library call.
pub fn lower_to_exec(
    module: &Module,
    plan: &FusionPlan,
    kernels: &[KernelPlan],
    generated_group_ids: &[usize],
) -> crate::Result<StitchedExecutable> {
    let comp = &module.entry;
    for instr in comp.instructions() {
        ensure_supported(instr.opcode).map_err(|e| anyhow!("%{} ({}): {e}", instr.id.0, instr.name))?;
    }

    let order = toposort_groups(comp, plan)?;
    let kmap: HashMap<usize, &KernelPlan> =
        generated_group_ids.iter().copied().zip(kernels.iter()).collect();

    let mut launches: Vec<Launch> = Vec::new();
    for gid in order {
        let group = &plan.groups[gid];
        match group.kind {
            GroupKind::Library => {
                launches.push(Launch::Library(lower_library(comp, group)?));
            }
            _ => {
                if let Some(&kplan) = kmap.get(&gid) {
                    launches.push(Launch::Kernel(lower_kernel(comp, group, kplan)?));
                }
                // groups without a kernel plan contain only free ops;
                // their values resolve through the free-op chain.
            }
        }
    }

    let params: Vec<ParamSpec> = comp
        .parameters()
        .into_iter()
        .map(|id| {
            let instr = comp.get(id);
            ParamSpec {
                id,
                name: instr.name.clone(),
                elems: instr.shape.num_elements() as usize,
            }
        })
        .collect();
    let consts: Vec<(InstrId, usize)> = comp
        .instructions()
        .filter(|i| i.opcode == Opcode::Constant)
        .map(|i| (i.id, i.shape.num_elements() as usize))
        .collect();

    let root = resolve_flat(comp, comp.root())?;
    let mut exe = StitchedExecutable {
        name: module.name.clone(),
        params,
        consts,
        launches,
        root,
        root_elems: comp.get(comp.root()).shape.num_elements() as usize,
        n_values: comp.len(),
        mem: memplan::MemoryPlan::unresolved(comp.len()),
    };
    // Static buffer assignment: liveness over the launch sequence,
    // lifetime-disjoint arena ranges, operand ranges baked into every
    // load (see `exec/memplan.rs`).
    memplan::resolve(&mut exe);
    Ok(exe)
}

/// Opcodes the stitched VM can execute. Everything else fails loudly at
/// lowering time (same policy as the op-by-op interpreter).
fn ensure_supported(op: Opcode) -> crate::Result<()> {
    use Opcode::*;
    match op {
        Parameter | Constant | Abs | Negate | Sign | Floor | Ceil | Not | Copy | Exp | Log
        | Sqrt | Rsqrt | Tanh | Sigmoid | Erf | Add | Subtract | Multiply | Maximum | Minimum
        | Compare | Divide | Power | Remainder | Select | Reshape | Bitcast | Transpose
        | Broadcast | Slice | Concatenate | Reduce | BatchDot | Dot | Convolution => Ok(()),
        other => bail!("opcode {other} is outside the stitched VM's executable subset"),
    }
}

/// Kahn toposort over the contracted group DAG (deterministic:
/// smallest-ready-id first).
fn toposort_groups(comp: &Computation, plan: &FusionPlan) -> crate::Result<Vec<usize>> {
    let n = plan.groups.len();
    let mut edges: HashSet<(usize, usize)> = HashSet::new();
    for id in comp.ids() {
        let Some(gu) = plan.group_of(id) else { continue };
        for &op in &comp.get(id).operands {
            // Dependency edges may flow through ungrouped free ops
            // (bitcast chains): resolve to the grouped producer, or the
            // producer group's launch could be ordered after its
            // consumer's.
            let mut src = op;
            while plan.group_of(src).is_none() && comp.get(src).opcode == Opcode::Bitcast {
                src = comp.get(src).operands[0];
            }
            if let Some(gp) = plan.group_of(src) {
                if gp.id != gu.id {
                    edges.insert((gp.id, gu.id));
                }
            }
        }
    }
    let mut indeg = vec![0usize; n];
    let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
    for &(a, b) in &edges {
        adj[a].push(b);
        indeg[b] += 1;
    }
    let mut ready: BTreeSet<usize> = (0..n).filter(|&g| indeg[g] == 0).collect();
    let mut order = Vec::with_capacity(n);
    loop {
        let g = match ready.iter().next() {
            Some(&g) => g,
            None => break,
        };
        ready.remove(&g);
        order.push(g);
        for &b in &adj[g] {
            indeg[b] -= 1;
            if indeg[b] == 0 {
                ready.insert(b);
            }
        }
    }
    if order.len() != n {
        bail!("fusion plan has an inter-group cycle; cannot lower");
    }
    Ok(order)
}

/// Resolve an instruction to the flat buffer that actually holds its
/// value (following zero-cost `Bitcast` aliases).
fn resolve_flat(comp: &Computation, mut id: InstrId) -> crate::Result<InstrId> {
    loop {
        let instr = comp.get(id);
        match instr.opcode {
            Opcode::Bitcast => id = instr.operands[0],
            Opcode::Tuple | Opcode::GetTupleElement | Opcode::While => {
                bail!("value of %{} ({}) is not a dense buffer", id.0, instr.opcode)
            }
            _ => return Ok(id),
        }
    }
}

fn lower_library(comp: &Computation, group: &FusionGroup) -> crate::Result<LibraryCall> {
    let id = *group.members.iter().next().expect("library groups are singletons");
    let instr = comp.get(id);
    let kind = match instr.opcode {
        Opcode::Dot => LibKind::Dot {
            lhs: buf_read(comp, instr.operands[0])?,
            rhs: buf_read(comp, instr.operands[1])?,
        },
        Opcode::Convolution => LibKind::Conv2d {
            input: buf_read(comp, instr.operands[0])?,
            filter: buf_read(comp, instr.operands[1])?,
        },
        op => bail!("library call {op} (%{}) cannot be executed by the stitched VM", id.0),
    };
    Ok(LibraryCall {
        op: id,
        out_dims: instr.shape.dims.clone(),
        out_elems: instr.shape.num_elements() as usize,
        kind,
        out_slot: None, // baked by the memory planner
    })
}

fn buf_read(comp: &Computation, id: InstrId) -> crate::Result<BufRead> {
    let dims = comp.get(id).shape.dims.clone();
    let src = resolve_flat(comp, id)?;
    Ok(BufRead { src, dims, slot: None })
}

/// Shared-slot metadata: where the owner's chunk lives and under which
/// schedule it was deposited.
struct SlotMeta {
    offset: usize,
    sched: Schedule,
    dims: Vec<i64>,
}

struct ExprCtx<'a> {
    comp: &'a Computation,
    members: &'a HashSet<InstrId>,
    slots: &'a HashMap<InstrId, SlotMeta>,
    /// Byte offset → index into the kernel's flat shared-region layout
    /// ([`KernelProgram::shm_regions`]).
    slot_of: &'a HashMap<usize, usize>,
    /// Fusion roots (globally materialized this launch) and the
    /// schedules their output loops run under — the visibility contract
    /// for same-launch reads of a root's output.
    root_scheds: &'a HashMap<InstrId, Schedule>,
    /// Ops materialized in grid-visible spill regions (third tier).
    /// After the grid fence that follows the spill write, any block may
    /// read any element — no chunk check, unlike shared/owned reads.
    spilled: &'a HashSet<InstrId>,
}

/// Builder for one straight-line [`ThreadProg`], memoizing repeated
/// `(value, index-map)` subexpressions so diamonds in the fused DAG do
/// not blow up the register file. `rank` is the dimensionality of the
/// index space the program is evaluated in — the affine specializer
/// compiles every load's index chain against it.
struct ProgBuilder {
    code: Vec<TInstr>,
    next: Reg,
    memo: HashMap<(InstrId, IndexMap), Reg>,
    rank: usize,
}

impl ProgBuilder {
    fn new(rank: usize) -> Self {
        ProgBuilder { code: Vec::new(), next: 0, memo: HashMap::new(), rank }
    }

    fn reg(&mut self) -> Reg {
        let r = self.next;
        self.next += 1;
        r
    }

    fn finish(self, out: Reg) -> ThreadProg {
        ThreadProg { n_regs: self.next, code: self.code, out }
    }
}

fn lower_kernel(
    comp: &Computation,
    group: &FusionGroup,
    kplan: &KernelPlan,
) -> crate::Result<KernelProgram> {
    let members = &group.members;
    // The VM only materializes roots globally: every member whose value
    // escapes the group must be a root, or the plan is unsound.
    for &m in members.iter() {
        let escapes = comp.users(m).iter().any(|u| !members.contains(u));
        if escapes && !group.roots.contains(&m) {
            bail!("group {}: member %{} escapes but is not a fusion root", group.id, m.0);
        }
    }

    let mut slots: HashMap<InstrId, SlotMeta> = HashMap::new();
    for (id, slot) in &kplan.shm.slots {
        let eop = kplan
            .ops
            .iter()
            .find(|o| o.id == *id)
            .ok_or_else(|| anyhow!("shared slot for %{} has no emitted op", id.0))?;
        let sched = match &eop.emitter {
            EmitterKind::Stitched(s) => *s,
            EmitterKind::Elemental => {
                bail!("shared-buffer op %{} was emitted elementally", id.0)
            }
        };
        slots.insert(
            *id,
            SlotMeta { offset: slot.offset, sched, dims: comp.get(*id).shape.dims.clone() },
        );
    }

    let mut root_scheds: HashMap<InstrId, Schedule> = HashMap::new();
    for eop in &kplan.ops {
        if eop.writes_output {
            let sched = match &eop.emitter {
                EmitterKind::Stitched(s) => *s,
                EmitterKind::Elemental => Schedule::fallback(),
            };
            root_scheds.insert(eop.id, sched);
        }
    }

    // Flat shared-memory layout for the fast path: one region per
    // distinct planner byte-offset, sized for the largest per-block
    // chunk deposited there (space-sharing owners rotate through the
    // same region, exactly like the byte offsets they share).
    let mut region_elems: std::collections::BTreeMap<usize, usize> = Default::default();
    for meta in slots.values() {
        let chunk = sched_chunk(meta.sched, &meta.dims).max(1) as usize;
        let e = region_elems.entry(meta.offset).or_insert(0);
        *e = (*e).max(chunk);
    }
    let mut shm_regions: Vec<ShmRegion> = Vec::with_capacity(region_elems.len());
    let mut slot_of: HashMap<usize, usize> = HashMap::new();
    let mut shm_base = 0usize;
    for (&off, &elems) in &region_elems {
        slot_of.insert(off, shm_regions.len());
        shm_regions.push(ShmRegion { base: shm_base, elems });
        shm_base += elems;
    }

    let spilled: HashSet<InstrId> = kplan.shm.spilled.iter().copied().collect();
    let ctx = ExprCtx {
        comp,
        members,
        slots: &slots,
        slot_of: &slot_of,
        root_scheds: &root_scheds,
        spilled: &spilled,
    };
    let mut steps: Vec<BlockStep> = Vec::new();
    let mut outputs: Vec<(InstrId, usize)> = Vec::new();
    let mut spills: Vec<(InstrId, usize)> = Vec::new();
    for eop in &kplan.ops {
        if !eop.writes_shared && !eop.writes_output && !eop.writes_spill {
            continue; // generator: thread-composed into consumers
        }
        let instr = comp.get(eop.id);
        let sched = match &eop.emitter {
            EmitterKind::Stitched(s) => *s,
            // Defensive: an inlined root still materializes its output;
            // one block covers the whole space.
            EmitterKind::Elemental => Schedule::fallback(),
        };
        let kind = lower_loop(&ctx, eop.id)?;
        let write = if eop.writes_shared {
            let meta = slots
                .get(&eop.id)
                .ok_or_else(|| anyhow!("%{} writes shared but has no slot", eop.id.0))?;
            WriteTarget::Shared { offset: meta.offset, slot: slot_of[&meta.offset] }
        } else if eop.writes_spill {
            WriteTarget::Spill
        } else {
            WriteTarget::Output
        };
        steps.push(BlockStep::Loop {
            op: eop.id,
            dims: instr.shape.dims.clone(),
            sched,
            kind,
            write,
        });
        if eop.writes_shared {
            steps.push(BlockStep::Barrier);
        }
        if eop.writes_spill {
            // Third tier: no block may read the spill region until
            // every block has deposited its chunk.
            steps.push(BlockStep::GridFence);
            spills.push((eop.id, instr.shape.num_elements() as usize));
        }
        if eop.writes_output {
            outputs.push((eop.id, instr.shape.num_elements() as usize));
        }
    }

    Ok(KernelProgram {
        name: kplan.name.clone(),
        group_id: group.id,
        blocks: kplan.blocks,
        threads: kplan.threads,
        shm_bytes: kplan.shm.total_bytes,
        shm_regions,
        steps,
        outputs,
        spills,
        group_fp: crate::fusion::group_fingerprint(comp, members),
        modeled_us: kplan.est_exec_us,
    })
}

fn lower_loop(ctx: &ExprCtx<'_>, id: InstrId) -> crate::Result<LoopKind> {
    let instr = ctx.comp.get(id);
    match instr.opcode {
        Opcode::Reduce => {
            let operand = instr.operands[0];
            let in_dims = ctx.comp.get(operand).shape.dims.clone();
            let dims = instr
                .attrs
                .reduce_dims
                .clone()
                .ok_or_else(|| anyhow!("reduce %{} missing dims", id.0))?;
            let kind = instr
                .attrs
                .reduce_kind
                .ok_or_else(|| anyhow!("reduce %{} missing kind", id.0))?;
            // Precomputed for the fast path's in-place index odometer.
            let kept: Vec<usize> = (0..in_dims.len()).filter(|d| !dims.contains(d)).collect();
            let sizes: Vec<i64> = dims.iter().map(|&d| in_dims[d]).collect();
            let mut pb = ProgBuilder::new(in_dims.len());
            let out = emit_expr(ctx, &mut pb, operand, IndexMap::identity(), true)?;
            Ok(LoopKind::Reduce { kind, dims, in_dims, operand: pb.finish(out), kept, sizes })
        }
        Opcode::BatchDot => {
            let (l, r) = (instr.operands[0], instr.operands[1]);
            let lhs_dims = ctx.comp.get(l).shape.dims.clone();
            let rhs_dims = ctx.comp.get(r).shape.dims.clone();
            let rank = instr.shape.dims.len();
            let mut pl = ProgBuilder::new(rank);
            let lo = emit_expr(ctx, &mut pl, l, IndexMap::identity(), true)?;
            let mut pr = ProgBuilder::new(rank);
            let ro = emit_expr(ctx, &mut pr, r, IndexMap::identity(), true)?;
            Ok(LoopKind::Dot { lhs: pl.finish(lo), rhs: pr.finish(ro), lhs_dims, rhs_dims })
        }
        _ => {
            let mut pb = ProgBuilder::new(instr.shape.dims.len());
            let out = emit_expr(ctx, &mut pb, id, IndexMap::identity(), false)?;
            Ok(LoopKind::Map { prog: pb.finish(out) })
        }
    }
}

/// Emit bytecode computing `id`'s value at the current evaluation index
/// transformed through `map`. With `allow_materialized`, shared-memory
/// and global buffers are read instead of recomputing (the normal case
/// for operands); the top-level op of a loop passes `false` so its own
/// expression is emitted.
fn emit_expr(
    ctx: &ExprCtx<'_>,
    pb: &mut ProgBuilder,
    id: InstrId,
    map: IndexMap,
    allow_materialized: bool,
) -> crate::Result<Reg> {
    if allow_materialized {
        if let Some(&r) = pb.memo.get(&(id, map.clone())) {
            return Ok(r);
        }
        let r = emit_expr_uncached(ctx, pb, id, map.clone(), true)?;
        pb.memo.insert((id, map), r);
        return Ok(r);
    }
    emit_expr_uncached(ctx, pb, id, map, false)
}

fn emit_expr_uncached(
    ctx: &ExprCtx<'_>,
    pb: &mut ProgBuilder,
    id: InstrId,
    map: IndexMap,
    allow_materialized: bool,
) -> crate::Result<Reg> {
    let instr = ctx.comp.get(id);
    if allow_materialized {
        if !ctx.members.contains(&id) {
            return emit_global(ctx, pb, id, map);
        }
        // Shared memory only serves chunk-aligned access paths. A slice
        // (`Offset`) crosses block chunks outright (Table 1 marks slice
        // operands recompute-per-block). A broadcast (`Gather`) path is
        // aligned when propagation *demanded* the owner's schedule
        // through it — guaranteed for reduce/batch-dot owners (they
        // cannot be recomputed, so propagation would have rejected a
        // misaligned edge) but not for elementwise owners, whose
        // unaligned broadcast edges propagation marks
        // recompute-per-block. Fall through to thread composition
        // whenever alignment is not guaranteed.
        let offset_free = !map.steps.iter().any(|s| matches!(s, IndexStep::Offset { .. }));
        let gather_free = !map.steps.iter().any(|s| matches!(s, IndexStep::Gather { .. }));
        let owner_mandatory = matches!(
            instr.opcode,
            Opcode::Reduce | Opcode::ReduceWindow | Opcode::BatchDot
        );
        let chunk_aligned = offset_free && (gather_free || owner_mandatory);
        if chunk_aligned {
            if let Some(meta) = ctx.slots.get(&id) {
                let dst = pb.reg();
                let sched_lin =
                    compile_affine_sched(&map, pb.rank, &meta.dims, meta.sched.sched_type);
                pb.code.push(TInstr::LoadShared {
                    dst,
                    offset: meta.offset,
                    owner: id,
                    owner_dims: meta.dims.clone(),
                    owner_sched: meta.sched,
                    slot: ctx.slot_of[&meta.offset],
                    chunk: sched_chunk(meta.sched, &meta.dims),
                    sched_lin,
                    map,
                });
                return Ok(dst);
            }
        }
    }
    use Opcode::*;
    match instr.opcode {
        Parameter => emit_global(ctx, pb, id, map),
        Constant => {
            let dst = pb.reg();
            pb.code.push(TInstr::Const { dst, value: CONST_FILL });
            Ok(dst)
        }
        Abs | Negate | Sign | Floor | Ceil | Not | Copy | Exp | Log | Sqrt | Rsqrt | Tanh
        | Sigmoid | Erf => {
            let a = emit_expr(ctx, pb, instr.operands[0], map, true)?;
            let dst = pb.reg();
            pb.code.push(TInstr::Unary { dst, a, op: unop_of(instr.opcode) });
            Ok(dst)
        }
        Add | Subtract | Multiply | Divide | Maximum | Minimum | Power | Remainder | Compare => {
            let a = emit_expr(ctx, pb, instr.operands[0], map.clone(), true)?;
            let b = emit_expr(ctx, pb, instr.operands[1], map, true)?;
            let dst = pb.reg();
            pb.code.push(TInstr::Binary { dst, a, b, op: binop_of(instr.opcode) });
            Ok(dst)
        }
        Select => {
            let p = emit_expr(ctx, pb, instr.operands[0], map.clone(), true)?;
            let t = emit_expr(ctx, pb, instr.operands[1], map.clone(), true)?;
            let f = emit_expr(ctx, pb, instr.operands[2], map, true)?;
            let dst = pb.reg();
            pb.code.push(TInstr::Select { dst, pred: p, on_true: t, on_false: f });
            Ok(dst)
        }
        Broadcast => {
            let bdims = instr
                .attrs
                .broadcast_dims
                .clone()
                .ok_or_else(|| anyhow!("broadcast %{} missing dims", id.0))?;
            emit_expr(ctx, pb, instr.operands[0], map.then(IndexStep::Gather { dims: bdims }), true)
        }
        Reshape | Bitcast => {
            let from = instr.shape.dims.clone();
            let to = ctx.comp.get(instr.operands[0]).shape.dims.clone();
            emit_expr(
                ctx,
                pb,
                instr.operands[0],
                map.then(IndexStep::Relinearize { from, to }),
                true,
            )
        }
        Transpose => {
            let perm = instr
                .attrs
                .transpose_perm
                .clone()
                .ok_or_else(|| anyhow!("transpose %{} missing perm", id.0))?;
            emit_expr(ctx, pb, instr.operands[0], map.then(IndexStep::Permute { perm }), true)
        }
        Slice => {
            let starts = instr
                .attrs
                .slice_starts
                .clone()
                .ok_or_else(|| anyhow!("slice %{} missing starts", id.0))?;
            emit_expr(ctx, pb, instr.operands[0], map.then(IndexStep::Offset { starts }), true)
        }
        Concatenate => {
            let cdim =
                instr.attrs.concat_dim.ok_or_else(|| anyhow!("concat %{} missing dim", id.0))?;
            let mut limits: Vec<i64> = Vec::new();
            let mut cases: Vec<ThreadProg> = Vec::new();
            let mut total = 0i64;
            for &o in &instr.operands {
                total += ctx.comp.get(o).shape.dims[cdim];
                limits.push(total);
                // Case programs evaluate at the rebased operand index,
                // whose rank equals the concat's.
                let mut sub = ProgBuilder::new(ctx.comp.get(o).shape.dims.len());
                let r = emit_expr(ctx, &mut sub, o, IndexMap::identity(), true)?;
                cases.push(sub.finish(r));
            }
            let dst = pb.reg();
            pb.code.push(TInstr::Branch { dst, map, dim: cdim, limits, cases });
            Ok(dst)
        }
        Reduce | BatchDot => {
            // A reduction/contraction cannot be thread-composed. A
            // spilled op (third tier) is materialized in a grid-visible
            // arena region before the grid fence, so any block may read
            // any element — a plain global load, no chunk check.
            if ctx.spilled.contains(&id) {
                let dst = pb.reg();
                let dims = instr.shape.dims.clone();
                let lin = compile_affine(&map, pb.rank, &dims);
                pb.code.push(TInstr::LoadGlobal {
                    dst,
                    src: id,
                    dims,
                    lin,
                    buf: None, // baked by the memory planner
                    map,
                });
                return Ok(dst);
            }
            // Otherwise the only remaining legal source is a fusion
            // root's own global output, readable within the executing
            // block's chunk.
            if let Some(&owner_sched) = ctx.root_scheds.get(&id) {
                let dst = pb.reg();
                let dims = instr.shape.dims.clone();
                let lin = compile_affine(&map, pb.rank, &dims);
                let sched_lin =
                    compile_affine_sched(&map, pb.rank, &dims, owner_sched.sched_type);
                pb.code.push(TInstr::LoadOwned {
                    dst,
                    src: id,
                    chunk: sched_chunk(owner_sched, &dims),
                    dims,
                    owner_sched,
                    lin,
                    sched_lin,
                    buf: None, // baked by the memory planner
                    map,
                });
                return Ok(dst);
            }
            bail!(
                "%{} ({}) is consumed in-group without a shared buffer — \
                 reductions/contractions cannot be thread-composed",
                id.0,
                instr.opcode
            )
        }
        op => bail!("opcode {op} is not executable by the stitched VM"),
    }
}

fn emit_global(
    ctx: &ExprCtx<'_>,
    pb: &mut ProgBuilder,
    id: InstrId,
    map: IndexMap,
) -> crate::Result<Reg> {
    let mut id = id;
    let mut map = map;
    loop {
        if ctx.members.contains(&id) {
            // bounced back into the group through an out-of-group bitcast
            return emit_expr(ctx, pb, id, map, true);
        }
        let instr = ctx.comp.get(id);
        match instr.opcode {
            Opcode::Bitcast => {
                let from = instr.shape.dims.clone();
                let to = ctx.comp.get(instr.operands[0]).shape.dims.clone();
                map = map.then(IndexStep::Relinearize { from, to });
                id = instr.operands[0];
            }
            Opcode::Constant => {
                let dst = pb.reg();
                pb.code.push(TInstr::Const { dst, value: CONST_FILL });
                return Ok(dst);
            }
            Opcode::Tuple | Opcode::GetTupleElement | Opcode::While => {
                bail!("value of %{} ({}) is not a dense buffer", id.0, instr.opcode)
            }
            _ => {
                let dst = pb.reg();
                let dims = instr.shape.dims.clone();
                let lin = compile_affine(&map, pb.rank, &dims);
                pb.code.push(TInstr::LoadGlobal {
                    dst,
                    src: id,
                    dims,
                    lin,
                    buf: None, // baked by the memory planner
                    map,
                });
                return Ok(dst);
            }
        }
    }
}

fn unop_of(op: Opcode) -> UnOp {
    match op {
        Opcode::Abs => UnOp::Abs,
        Opcode::Negate => UnOp::Neg,
        Opcode::Sign => UnOp::Sign,
        Opcode::Floor => UnOp::Floor,
        Opcode::Ceil => UnOp::Ceil,
        Opcode::Not => UnOp::Not,
        Opcode::Copy => UnOp::Id,
        Opcode::Exp => UnOp::Exp,
        Opcode::Log => UnOp::Log,
        Opcode::Sqrt => UnOp::Sqrt,
        Opcode::Rsqrt => UnOp::Rsqrt,
        Opcode::Tanh => UnOp::Tanh,
        Opcode::Sigmoid => UnOp::Sigmoid,
        Opcode::Erf => UnOp::Erf,
        _ => unreachable!("not a unary opcode: {op}"),
    }
}

fn binop_of(op: Opcode) -> super::bytecode::BinOp {
    use super::bytecode::BinOp;
    match op {
        Opcode::Add => BinOp::Add,
        Opcode::Subtract => BinOp::Sub,
        Opcode::Multiply => BinOp::Mul,
        Opcode::Divide => BinOp::Div,
        Opcode::Maximum => BinOp::Max,
        Opcode::Minimum => BinOp::Min,
        Opcode::Power => BinOp::Pow,
        Opcode::Remainder => BinOp::Rem,
        Opcode::Compare => BinOp::Gt,
        _ => unreachable!("not a binary opcode: {op}"),
    }
}
