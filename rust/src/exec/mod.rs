//! Stitched execution — the compiler's output, actually run.
//!
//! Everything upstream (fusion §3, schedule planning §4, codegen §5)
//! produces *plans*; this subsystem executes them. A compiled module
//! lowers ([`lower`]) into a [`StitchedExecutable`] — register bytecode
//! ([`bytecode`]) modeling the GPU grid explicitly — and the VM
//! ([`machine`]) runs the whole module as **one launch per fused
//! group**, with per-block shared-memory regions, barrier fences and
//! thread loops. A [`LaunchLedger`] ([`ledger`]) records
//! generated-kernel vs library-call launches, so the paper's headline
//! launch-reduction claim (Fig. 7) is measured on real executions
//! instead of estimated from the partition.
//!
//! Paper §5 ↔ module map:
//!
//! | paper | here |
//! |---|---|
//! | Algorithm 2 emitter dispatch | [`lower`] (follows the `KernelPlan`'s records) |
//! | per-op parallel loops (Fig. 5) | [`bytecode::BlockStep::Loop`] + chunk model |
//! | thread composition | inlined [`bytecode::ThreadProg`] expressions |
//! | block composition via shared memory | per-block regions + [`bytecode::BlockStep::Barrier`] |
//! | global-memory stitching (third tier) | spill regions + [`bytecode::BlockStep::GridFence`] phases |
//! | kernel launch counts (Fig. 7) | [`LaunchLedger`] (attributed per [`StitchTier`]) |

//!
//! Since the memory-planning PR the execute path itself is fast: a
//! static buffer-assignment pass ([`memplan`]) packs every value into
//! one flat arena with lifetime-disjoint reuse, loads carry compiled
//! affine offsets and resolved arena ranges, and each launch's grid
//! loop fans out over cores ([`par`]) — with outputs and ledgers
//! bit-identical to the boxed reference path
//! ([`StitchedExecutable::run_boxed`]) at any thread count.

pub mod bytecode;
pub mod ledger;
pub mod lower;
pub mod machine;
pub mod memplan;
pub mod par;

pub use bytecode::{KernelProgram, StitchTier};
pub use ledger::LaunchLedger;
pub use lower::lower_to_exec;
pub use machine::{ExecArena, Launch, LibKind, LibraryCall, StitchedExecutable};
pub use memplan::{ArenaStats, BufSlot, MemoryPlan};
