//! The launch ledger: what a served request actually cost in kernel
//! launches.
//!
//! The paper's headline claim (Fig. 7) is a reduction in *GPU kernel
//! launches*; everything upstream of this module only predicted that
//! number. The ledger records launches as they are executed by the
//! stitched VM ([`crate::exec::machine`]) or by the op-by-op
//! interpreter, so the reduction can be measured on real runs instead
//! of estimated from the fusion plan. Generated launches are further
//! attributed to the stitching tier that produced them (plain / shm /
//! global), so benches and serving stats can tell which tier earned a
//! launch reduction.

use std::fmt;

/// Counters accumulated while executing a compiled program.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LaunchLedger {
    /// Generated (stitched or loop) kernel launches — one per fused
    /// group per execution.
    pub generated: u64,
    /// Vendor-library call launches (`Dot`/`Convolution` class).
    pub library: u64,
    /// `__syncthreads`-style barriers executed across all blocks.
    pub barriers: u64,
    /// Grid-wide fences executed across all blocks (one count per
    /// block per `GridFence` step — the global-tier sync cost).
    pub fences: u64,
    /// Block iterations simulated (grid size summed over launches).
    pub block_iters: u64,
    /// Output elements produced by thread loops (work volume).
    pub thread_elems: u64,
    /// Generated launches with no cross-emitter intermediates.
    pub tier_plain: u64,
    /// Generated launches stitched through shared memory (§5.1).
    pub tier_shm: u64,
    /// Generated launches stitched through global-memory spill regions
    /// with grid fences (the third tier).
    pub tier_global: u64,
}

impl LaunchLedger {
    /// Total kernel launches, the Fig. 7 numerator/denominator
    /// (generated kernels plus library calls).
    pub fn total_launches(&self) -> u64 {
        self.generated + self.library
    }

    /// Accumulate another ledger into this one.
    pub fn merge(&mut self, other: &LaunchLedger) {
        self.generated += other.generated;
        self.library += other.library;
        self.barriers += other.barriers;
        self.fences += other.fences;
        self.block_iters += other.block_iters;
        self.thread_elems += other.thread_elems;
        self.tier_plain += other.tier_plain;
        self.tier_shm += other.tier_shm;
        self.tier_global += other.tier_global;
    }

    /// Field-wise difference (`self - earlier`), for deriving the cost
    /// of one execution from two cumulative snapshots.
    pub fn since(&self, earlier: &LaunchLedger) -> LaunchLedger {
        LaunchLedger {
            generated: self.generated.saturating_sub(earlier.generated),
            library: self.library.saturating_sub(earlier.library),
            barriers: self.barriers.saturating_sub(earlier.barriers),
            fences: self.fences.saturating_sub(earlier.fences),
            block_iters: self.block_iters.saturating_sub(earlier.block_iters),
            thread_elems: self.thread_elems.saturating_sub(earlier.thread_elems),
            tier_plain: self.tier_plain.saturating_sub(earlier.tier_plain),
            tier_shm: self.tier_shm.saturating_sub(earlier.tier_shm),
            tier_global: self.tier_global.saturating_sub(earlier.tier_global),
        }
    }
}

impl fmt::Display for LaunchLedger {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "launches: {} generated + {} library (barriers {}, fences {}, blocks {}, elems {}, tiers plain/shm/global {}/{}/{})",
            self.generated,
            self.library,
            self.barriers,
            self.fences,
            self.block_iters,
            self.thread_elems,
            self.tier_plain,
            self.tier_shm,
            self.tier_global
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_and_since_roundtrip() {
        let mut a = LaunchLedger {
            generated: 3,
            library: 1,
            barriers: 5,
            fences: 2,
            block_iters: 8,
            thread_elems: 100,
            tier_plain: 1,
            tier_shm: 1,
            tier_global: 1,
        };
        let b = LaunchLedger {
            generated: 2,
            library: 2,
            barriers: 1,
            fences: 1,
            block_iters: 4,
            thread_elems: 50,
            tier_plain: 0,
            tier_shm: 1,
            tier_global: 1,
        };
        let before = a;
        a.merge(&b);
        assert_eq!(a.total_launches(), 8);
        assert_eq!(a.since(&before), b);
    }

    #[test]
    fn display_mentions_both_kinds() {
        let l = LaunchLedger { generated: 2, library: 3, ..Default::default() };
        let s = l.to_string();
        assert!(s.contains("2 generated") && s.contains("3 library"));
    }

    #[test]
    fn display_mentions_tiers_and_fences() {
        let l = LaunchLedger {
            generated: 3,
            fences: 4,
            tier_plain: 1,
            tier_shm: 1,
            tier_global: 1,
            ..Default::default()
        };
        let s = l.to_string();
        assert!(s.contains("fences 4"));
        assert!(s.contains("tiers plain/shm/global 1/1/1"));
    }
}
