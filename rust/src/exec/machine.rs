//! The stitched VM: executes lowered bytecode with an explicit grid.
//!
//! A [`StitchedExecutable`] runs a whole compiled module as **one
//! launch per fused group** (plus one per library call), recording a
//! [`LaunchLedger`]. Each kernel launch iterates its grid: per block,
//! the block's shared-memory regions are materialized as buffers; per
//! stitched loop, a thread loop strides the block's chunk computing one
//! output element per [`ThreadProg`] evaluation.
//!
//! The VM deliberately enforces the stitching invariants instead of
//! papering over them:
//!
//! - a [`TInstr::LoadShared`] whose mapped index falls outside the
//!   executing block's chunk of the owner is an error (schedule
//!   propagation should have made chunks line up — §4.2);
//! - a shared region read while a different op owns it is an error (the
//!   §5.1.3 dominance rule should have prevented the reuse);
//! - kernel outputs only ever come from fusion roots — in-group
//!   consumers recompute or read shared memory, never global output
//!   written in the same launch (no cross-block synchronization).

use super::bytecode::{
    chunk_index, chunk_offset, linearize, sched_blocks, sched_chunk, BlockStep, KernelProgram,
    LoopKind, TInstr, ThreadProg, WriteTarget, CONST_FILL,
};
use super::ledger::LaunchLedger;
use crate::hlo::instruction::ReduceKind;
use crate::hlo::InstrId;
use anyhow::{anyhow, bail};
use std::collections::HashMap;

/// One entry parameter of the executable.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub id: InstrId,
    pub name: String,
    pub elems: usize,
}

/// A flat-buffer read: the resolved source instruction and the dims the
/// reader sees (bitcast aliases resolved at lowering).
#[derive(Debug, Clone, PartialEq)]
pub struct BufRead {
    pub src: InstrId,
    pub dims: Vec<i64>,
}

/// A vendor-library launch (cuBLAS/cuDNN class — LC-layer ops).
#[derive(Debug, Clone, PartialEq)]
pub enum LibKind {
    /// `[..., m, k] x [..., k, n] -> [..., m, n]`, k ascending.
    Dot { lhs: BufRead, rhs: BufRead },
    /// NHWC input, HWIO filter, stride 1, SAME padding.
    Conv2d { input: BufRead, filter: BufRead },
}

#[derive(Debug, Clone, PartialEq)]
pub struct LibraryCall {
    pub op: InstrId,
    pub out_dims: Vec<i64>,
    pub out_elems: usize,
    pub kind: LibKind,
}

/// One launch of the compiled module.
#[derive(Debug, Clone, PartialEq)]
pub enum Launch {
    Kernel(KernelProgram),
    Library(LibraryCall),
}

/// A whole lowered module, ready to run: the compiler's executable
/// artifact. Launches are in dependency (topological group) order.
#[derive(Debug, Clone, PartialEq)]
pub struct StitchedExecutable {
    pub name: String,
    /// Entry parameters in parameter-number order.
    pub params: Vec<ParamSpec>,
    /// Valueless IR constants, materialized as `CONST_FILL`.
    pub consts: Vec<(InstrId, usize)>,
    pub launches: Vec<Launch>,
    /// Buffer holding the module's result (bitcasts resolved).
    pub root: InstrId,
    pub root_elems: usize,
    /// Size of the value arena (instruction count of the source module).
    pub n_values: usize,
}

impl StitchedExecutable {
    /// Generated-kernel launches per execution (the Fig. 7 count).
    pub fn generated_launches(&self) -> u64 {
        self.launches.iter().filter(|l| matches!(l, Launch::Kernel(_))).count() as u64
    }

    /// Library-call launches per execution.
    pub fn library_launches(&self) -> u64 {
        self.launches.iter().filter(|l| matches!(l, Launch::Library(_))).count() as u64
    }

    /// Disassembly of every kernel launch (diagnostics / tests).
    pub fn disasm(&self) -> String {
        let mut out = String::new();
        for launch in &self.launches {
            match launch {
                Launch::Kernel(k) => out.push_str(&k.disasm()),
                Launch::Library(l) => {
                    let kind = match l.kind {
                        LibKind::Dot { .. } => "dot",
                        LibKind::Conv2d { .. } => "conv2d",
                    };
                    out.push_str(&format!("library %{} {}\n", l.op.0, kind));
                }
            }
        }
        out
    }

    /// Execute with one flattened f32 buffer per parameter; returns the
    /// module result and the launch ledger of this run.
    pub fn run(&self, inputs: &[Vec<f32>]) -> crate::Result<(Vec<f32>, LaunchLedger)> {
        if inputs.len() != self.params.len() {
            bail!("{}: expected {} inputs, got {}", self.name, self.params.len(), inputs.len());
        }
        let mut values: Vec<Option<Vec<f32>>> = vec![None; self.n_values];
        for (spec, buf) in self.params.iter().zip(inputs) {
            if buf.len() != spec.elems {
                bail!(
                    "{}: parameter {} expects {} elements, got {}",
                    self.name,
                    spec.name,
                    spec.elems,
                    buf.len()
                );
            }
            values[spec.id.0] = Some(buf.clone());
        }
        for &(id, elems) in &self.consts {
            values[id.0] = Some(vec![CONST_FILL; elems.max(1)]);
        }

        let mut ledger = LaunchLedger::default();
        for launch in &self.launches {
            match launch {
                Launch::Kernel(k) => {
                    run_kernel(k, &mut values, &mut ledger)?;
                    ledger.generated += 1;
                }
                Launch::Library(l) => {
                    run_library(l, &mut values)?;
                    ledger.library += 1;
                }
            }
        }

        let out = values[self.root.0]
            .clone()
            .ok_or_else(|| anyhow!("{}: root value was never produced", self.name))?;
        Ok((out, ledger))
    }
}

/// Per-block evaluation context handed to thread programs.
struct EvalCtx<'a> {
    values: &'a [Option<Vec<f32>>],
    shm: &'a HashMap<usize, (InstrId, Vec<f32>)>,
    block: i64,
}

fn run_kernel(
    k: &KernelProgram,
    values: &mut [Option<Vec<f32>>],
    ledger: &mut LaunchLedger,
) -> crate::Result<()> {
    for &(root, elems) in &k.outputs {
        values[root.0] = Some(vec![0f32; elems]);
    }
    let threads = k.threads.max(1) as i64;
    for b in 0..k.blocks.max(1) as i64 {
        // Shared memory: byte-offset-keyed regions; a SHARE rewrite
        // replaces the previous owner (space sharing, §5.1.3).
        let mut shm: HashMap<usize, (InstrId, Vec<f32>)> = HashMap::new();
        for step in &k.steps {
            match step {
                BlockStep::Barrier => ledger.barriers += 1,
                BlockStep::Loop { op, dims, sched, kind, write } => {
                    let grid = sched_blocks(*sched, dims);
                    if b >= grid {
                        continue; // guarded-off block for this loop
                    }
                    let chunk = sched_chunk(*sched, dims);
                    let mut vals = vec![0f32; chunk as usize];
                    {
                        let ctx = EvalCtx { values: &values[..], shm: &shm, block: b };
                        for t in 0..threads {
                            let mut e = t;
                            while e < chunk {
                                let idx = chunk_index(*sched, dims, b, e);
                                vals[e as usize] = compute_element(kind, &idx, &ctx)
                                    .map_err(|err| anyhow!("kernel {} %{}: {err}", k.name, op.0))?;
                                ledger.thread_elems += 1;
                                e += threads;
                            }
                        }
                    }
                    match write {
                        WriteTarget::Shared { offset } => {
                            shm.insert(*offset, (*op, vals));
                        }
                        WriteTarget::Output => {
                            let buf = values[op.0]
                                .as_mut()
                                .ok_or_else(|| anyhow!("output %{} not allocated", op.0))?;
                            for e in 0..chunk {
                                let idx = chunk_index(*sched, dims, b, e);
                                let lin = linearize(&idx, dims) as usize;
                                buf[lin] = vals[e as usize];
                            }
                        }
                    }
                }
            }
        }
        ledger.block_iters += 1;
    }
    Ok(())
}

fn compute_element(kind: &LoopKind, idx: &[i64], ctx: &EvalCtx<'_>) -> crate::Result<f32> {
    match kind {
        LoopKind::Map { prog } => eval_prog(prog, idx, ctx),
        LoopKind::Reduce { kind, dims, in_dims, operand } => {
            // Rebuild the input index: kept dims take the output index,
            // reduced dims iterate row-major (dims ascending) — the same
            // order the op-by-op interpreter uses, so accumulation is
            // bit-identical.
            let kept: Vec<usize> = (0..in_dims.len()).filter(|d| !dims.contains(d)).collect();
            let mut in_idx = vec![0i64; in_dims.len()];
            for (k, &d) in kept.iter().enumerate() {
                in_idx[d] = idx[k];
            }
            let sizes: Vec<i64> = dims.iter().map(|&d| in_dims[d]).collect();
            let n: i64 = sizes.iter().product::<i64>().max(1);
            let mut acc = reduce_init(*kind);
            for it in 0..n {
                let sub = super::bytecode::delinearize(it, &sizes);
                for (j, &d) in dims.iter().enumerate() {
                    in_idx[d] = sub[j];
                }
                let v = eval_prog(operand, &in_idx, ctx)?;
                acc = reduce_combine(*kind, acc, v);
            }
            Ok(reduce_finish(*kind, acc, n))
        }
        LoopKind::Dot { lhs, rhs, lhs_dims, rhs_dims } => {
            let r = idx.len();
            debug_assert!(r >= 2);
            let kk = lhs_dims[r - 1];
            debug_assert_eq!(kk, rhs_dims[r - 2]);
            let mut lhs_idx = idx.to_vec();
            let mut rhs_idx = idx.to_vec();
            let mut acc = 0f32;
            for k in 0..kk {
                lhs_idx[r - 1] = k;
                rhs_idx[r - 2] = k;
                acc += eval_prog(lhs, &lhs_idx, ctx)? * eval_prog(rhs, &rhs_idx, ctx)?;
            }
            Ok(acc)
        }
    }
}

pub(crate) fn reduce_init(kind: ReduceKind) -> f32 {
    match kind {
        ReduceKind::Sum | ReduceKind::Mean => 0.0,
        ReduceKind::Max => f32::NEG_INFINITY,
        ReduceKind::Min => f32::INFINITY,
        ReduceKind::Prod => 1.0,
    }
}

pub(crate) fn reduce_combine(kind: ReduceKind, acc: f32, v: f32) -> f32 {
    match kind {
        ReduceKind::Sum | ReduceKind::Mean => acc + v,
        ReduceKind::Max => acc.max(v),
        ReduceKind::Min => acc.min(v),
        ReduceKind::Prod => acc * v,
    }
}

pub(crate) fn reduce_finish(kind: ReduceKind, acc: f32, n: i64) -> f32 {
    match kind {
        ReduceKind::Mean => acc / n as f32,
        _ => acc,
    }
}

fn eval_prog(prog: &ThreadProg, idx: &[i64], ctx: &EvalCtx<'_>) -> crate::Result<f32> {
    let mut regs = vec![0f32; prog.n_regs.max(1) as usize];
    for ins in &prog.code {
        match ins {
            TInstr::Const { dst, value } => regs[*dst as usize] = *value,
            TInstr::LoadGlobal { dst, src, dims, map } => {
                let j = map.apply(idx);
                let lin = linearize(&j, dims);
                let buf = ctx.values[src.0]
                    .as_ref()
                    .ok_or_else(|| anyhow!("value %{} read before it was produced", src.0))?;
                regs[*dst as usize] = *buf.get(lin as usize).ok_or_else(|| {
                    anyhow!("%{}: index {j:?} out of bounds for dims {dims:?}", src.0)
                })?;
            }
            TInstr::LoadShared { dst, offset, owner, owner_dims, owner_sched, map } => {
                let j = map.apply(idx);
                let (holder, buf) = ctx.shm.get(offset).ok_or_else(|| {
                    anyhow!("shared region at offset {offset} read before any write")
                })?;
                if holder != owner {
                    bail!(
                        "shared region at offset {offset} holds %{} but %{} was expected \
                         (space-sharing violation)",
                        holder.0,
                        owner.0
                    );
                }
                let local = chunk_offset(*owner_sched, owner_dims, ctx.block, &j).ok_or_else(
                    || {
                        anyhow!(
                            "block {} reads %{} at {j:?}, outside its shared chunk \
                             (stitching invariant violated)",
                            ctx.block,
                            owner.0
                        )
                    },
                )?;
                regs[*dst as usize] = buf[local as usize];
            }
            TInstr::LoadOwned { dst, src, dims, owner_sched, map } => {
                let j = map.apply(idx);
                if chunk_offset(*owner_sched, dims, ctx.block, &j).is_none() {
                    bail!(
                        "block {} reads root %{} at {j:?}, outside its own chunk \
                         (no cross-block synchronization exists)",
                        ctx.block,
                        src.0
                    );
                }
                let lin = linearize(&j, dims) as usize;
                let buf = ctx.values[src.0]
                    .as_ref()
                    .ok_or_else(|| anyhow!("root %{} output not allocated", src.0))?;
                regs[*dst as usize] = buf[lin];
            }
            TInstr::Unary { dst, a, op } => {
                regs[*dst as usize] = op.apply(regs[*a as usize]);
            }
            TInstr::Binary { dst, a, b, op } => {
                regs[*dst as usize] = op.apply(regs[*a as usize], regs[*b as usize]);
            }
            TInstr::Select { dst, pred, on_true, on_false } => {
                regs[*dst as usize] = if regs[*pred as usize] != 0.0 {
                    regs[*on_true as usize]
                } else {
                    regs[*on_false as usize]
                };
            }
            TInstr::Branch { dst, map, dim, limits, cases } => {
                let mut j = map.apply(idx);
                let x = j[*dim];
                let mut case = None;
                let mut prev = 0i64;
                for (i, &l) in limits.iter().enumerate() {
                    if x < l {
                        case = Some((i, prev));
                        break;
                    }
                    prev = l;
                }
                let (ci, start) =
                    case.ok_or_else(|| anyhow!("concat index {x} out of range {limits:?}"))?;
                j[*dim] = x - start;
                regs[*dst as usize] = eval_prog(&cases[ci], &j, ctx)?;
            }
        }
    }
    Ok(regs[prog.out as usize])
}

fn read_buf<'a>(
    values: &'a [Option<Vec<f32>>],
    r: &BufRead,
) -> crate::Result<&'a [f32]> {
    values[r.src.0]
        .as_deref()
        .ok_or_else(|| anyhow!("library operand %{} not yet produced", r.src.0))
}

fn run_library(l: &LibraryCall, values: &mut [Option<Vec<f32>>]) -> crate::Result<()> {
    let out = match &l.kind {
        LibKind::Dot { lhs, rhs } => {
            let a = read_buf(&values[..], lhs)?;
            let b = read_buf(&values[..], rhs)?;
            dot(a, &lhs.dims, b, &rhs.dims, &l.out_dims)
        }
        LibKind::Conv2d { input, filter } => {
            let x = read_buf(&values[..], input)?;
            let w = read_buf(&values[..], filter)?;
            conv2d_same(x, &input.dims, w, &filter.dims, &l.out_dims)
        }
    };
    values[l.op.0] = Some(out);
    Ok(())
}

/// Batched matmul `[..., m, k] x [..., k, n] -> [..., m, n]`; the exact
/// loop order (k innermost, ascending) is shared with the interpreter
/// so results are bit-identical.
pub(crate) fn dot(
    a: &[f32],
    a_dims: &[i64],
    b: &[f32],
    b_dims: &[i64],
    out_dims: &[i64],
) -> Vec<f32> {
    let r = out_dims.len();
    let batch: i64 = out_dims[..r - 2].iter().product::<i64>().max(1);
    let m = out_dims[r - 2];
    let n = out_dims[r - 1];
    let k = a_dims[r - 1];
    debug_assert_eq!(k, b_dims[r - 2]);
    let mut out = vec![0f32; (batch * m * n) as usize];
    for bi in 0..batch {
        let ao = (bi * m * k) as usize;
        let bo = (bi * k * n) as usize;
        let oo = (bi * m * n) as usize;
        for i in 0..m as usize {
            for j in 0..n as usize {
                let mut acc = 0f32;
                for kk in 0..k as usize {
                    acc += a[ao + i * k as usize + kk] * b[bo + kk * n as usize + j];
                }
                out[oo + i * n as usize + j] = acc;
            }
        }
    }
    out
}

/// NHWC x HWIO convolution, stride 1, SAME padding (zero fill), the
/// shape contract of [`crate::hlo::GraphBuilder::conv2d`].
pub(crate) fn conv2d_same(
    x: &[f32],
    x_dims: &[i64],
    w: &[f32],
    w_dims: &[i64],
    out_dims: &[i64],
) -> Vec<f32> {
    let (n, h, wd, c) = (x_dims[0], x_dims[1], x_dims[2], x_dims[3]);
    let (kh, kw, _ci, co) = (w_dims[0], w_dims[1], w_dims[2], w_dims[3]);
    let pad_h = (kh - 1) / 2;
    let pad_w = (kw - 1) / 2;
    let mut out = vec![0f32; out_dims.iter().product::<i64>() as usize];
    let xi = |ni: i64, hi: i64, wi: i64, ci2: i64| -> f32 {
        if hi < 0 || hi >= h || wi < 0 || wi >= wd {
            0.0
        } else {
            x[(((ni * h + hi) * wd + wi) * c + ci2) as usize]
        }
    };
    let mut o = 0usize;
    for ni in 0..n {
        for hi in 0..h {
            for wi in 0..wd {
                for oi in 0..co {
                    let mut acc = 0f32;
                    for khi in 0..kh {
                        for kwi in 0..kw {
                            for ci2 in 0..c {
                                let xv = xi(ni, hi + khi - pad_h, wi + kwi - pad_w, ci2);
                                let wv = w[(((khi * kw + kwi) * c + ci2) * co + oi) as usize];
                                acc += xv * wv;
                            }
                        }
                    }
                    out[o] = acc;
                    o += 1;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::{compile_module, FusionMode, PipelineConfig};
    use crate::exec::lower::lower_to_exec;
    use crate::gpusim::DeviceConfig;
    use crate::hlo::instruction::ReduceKind;
    use crate::hlo::{GraphBuilder, Module, Shape};
    use crate::schedule::PerfLibrary;

    fn compile_and_lower(module: &Module, mode: FusionMode) -> StitchedExecutable {
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let cfg = PipelineConfig::default();
        let compiled = compile_module(module, mode, &mut lib, &cfg).unwrap();
        lower_to_exec(module, &compiled.plan, &compiled.kernels, &compiled.generated_group_ids)
            .unwrap()
    }

    fn fill(n: usize, seed: u64) -> Vec<f32> {
        (0..n)
            .map(|i| {
                let h = (i as u64).wrapping_mul(2654435761).wrapping_add(seed * 97);
                ((h % 1000) as f32) / 1000.0 - 0.5
            })
            .collect()
    }

    /// Reference softmax(scores) @ v over the last dim of [b, s, s].
    fn softmax_bmm_ref(scores: &[f32], v: &[f32], b: usize, s: usize, d: usize) -> Vec<f32> {
        let mut out = vec![0f32; b * s * d];
        for bi in 0..b {
            for i in 0..s {
                let row = &scores[bi * s * s + i * s..bi * s * s + (i + 1) * s];
                let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let e: Vec<f32> = row.iter().map(|&x| (x - m).exp()).collect();
                let sum: f32 = e.iter().sum();
                for j in 0..d {
                    let mut acc = 0f32;
                    for kk in 0..s {
                        acc += (e[kk] / sum) * v[bi * s * d + kk * d + j];
                    }
                    out[bi * s * d + i * d + j] = acc;
                }
            }
        }
        out
    }

    #[test]
    fn figure3_kernel_executes_softmax_bmm() {
        // The paper's motivating pattern as ONE launch.
        let (bs, s, d) = (4usize, 16usize, 8usize);
        let mut b = GraphBuilder::new("fig3");
        let scores = b.param("scores", Shape::f32(&[bs as i64, s as i64, s as i64]));
        let v = b.param("v", Shape::f32(&[bs as i64, s as i64, d as i64]));
        let m = b.reduce(scores, &[2], ReduceKind::Max);
        let mb = b.broadcast(m, &[bs as i64, s as i64, s as i64], &[0, 1]);
        let sh = b.sub(scores, mb);
        let e = b.exp(sh);
        let sm = b.reduce(e, &[2], ReduceKind::Sum);
        let sb = b.broadcast(sm, &[bs as i64, s as i64, s as i64], &[0, 1]);
        let p = b.div(e, sb);
        let out = b.batch_dot(p, v);
        let module = Module::new("fig3", b.finish(out));

        let mut cfg = PipelineConfig::default();
        cfg.deep.fuse_batch_dot = true;
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let compiled =
            compile_module(&module, FusionMode::FusionStitching, &mut lib, &cfg).unwrap();
        let exe = lower_to_exec(
            &module,
            &compiled.plan,
            &compiled.kernels,
            &compiled.generated_group_ids,
        )
        .unwrap();

        let scores_v = fill(bs * s * s, 1);
        let v_v = fill(bs * s * d, 2);
        let (got, ledger) = exe.run(&[scores_v.clone(), v_v.clone()]).unwrap();
        let want = softmax_bmm_ref(&scores_v, &v_v, bs, s, d);
        let max_diff = got
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_diff < 1e-5, "stitched softmax-bmm diverged: {max_diff}");
        // With batch-dot fusion on, the whole pattern is few launches —
        // far fewer than the 8 per-op kernels.
        assert!(ledger.total_launches() < 8, "{ledger}");
        assert!(ledger.generated >= 1);
        assert!(ledger.barriers > 0, "shared-memory stitching must fence: {ledger}");
    }

    #[test]
    fn baseline_and_stitched_agree_on_elementwise_chain() {
        let mut b = GraphBuilder::new("chain");
        let x = b.param("x", Shape::f32(&[32, 24]));
        let y = b.param("y", Shape::f32(&[32, 24]));
        let e = b.exp(x);
        let a = b.add(e, y);
        let t = b.tanh(a);
        let g = b.compare(t, y);
        let sel = b.select(g, t, y);
        let r = b.reduce(sel, &[1], ReduceKind::Mean);
        let module = Module::new("chain", b.finish(r));

        let base = compile_and_lower(&module, FusionMode::XlaBaseline);
        let fs = compile_and_lower(&module, FusionMode::FusionStitching);
        let xs = fill(32 * 24, 3);
        let ys = fill(32 * 24, 4);
        let (ob, lb) = base.run(&[xs.clone(), ys.clone()]).unwrap();
        let (of, lf) = fs.run(&[xs, ys]).unwrap();
        assert_eq!(ob.len(), 32);
        let max_diff =
            ob.iter().zip(&of).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
        assert!(max_diff < 1e-5, "modes diverged: {max_diff}");
        assert!(
            lf.total_launches() <= lb.total_launches(),
            "deep fusion must not launch more: {lf} vs {lb}"
        );
    }

    #[test]
    fn library_dot_and_conv_execute() {
        let mut b = GraphBuilder::new("lib");
        let x = b.param("x", Shape::f32(&[2, 3]));
        let w = b.param("w", Shape::f32(&[3, 2]));
        let d = b.dot(x, w);
        let t = b.tanh(d);
        let module = Module::new("lib", b.finish(t));
        let exe = compile_and_lower(&module, FusionMode::FusionStitching);
        let (out, ledger) = exe
            .run(&[vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]])
            .unwrap();
        // row0: [1,2,3] x cols [1,0,1]^T etc: [1*1+2*0+3*1, 1*0+2*1+3*1] = [4, 5]
        assert!((out[0] - (4.0f32).tanh()).abs() < 1e-6);
        assert!((out[1] - (5.0f32).tanh()).abs() < 1e-6);
        assert_eq!(ledger.library, 1);
        assert!(ledger.generated >= 1);
    }

    #[test]
    fn conv2d_same_matches_manual() {
        // 1x3x3x1 input, 3x3x1x1 filter of ones: each output = sum of
        // the 3x3 neighborhood (zero padded).
        let x: Vec<f32> = (1..=9).map(|v| v as f32).collect();
        let w = vec![1.0f32; 9];
        let out = conv2d_same(&x, &[1, 3, 3, 1], &w, &[3, 3, 1, 1], &[1, 3, 3, 1]);
        // center = sum(1..9) = 45; corner (0,0) = 1+2+4+5 = 12
        assert_eq!(out[4], 45.0);
        assert_eq!(out[0], 12.0);
    }

    #[test]
    fn arity_and_size_checked() {
        let mut b = GraphBuilder::new("m");
        let x = b.param("x", Shape::f32(&[4]));
        let t = b.tanh(x);
        let module = Module::new("m", b.finish(t));
        let exe = compile_and_lower(&module, FusionMode::FusionStitching);
        assert!(exe.run(&[]).is_err());
        assert!(exe.run(&[vec![0.0; 3]]).is_err());
        assert!(exe.run(&[vec![0.0; 4]]).is_ok());
    }

    #[test]
    fn disasm_shows_loops_and_barriers() {
        let mut b = GraphBuilder::new("d");
        let x = b.param("x", Shape::f32(&[8, 32]));
        let e = b.exp(x);
        let r = b.reduce(e, &[1], ReduceKind::Sum);
        let rb = b.broadcast(r, &[8, 32], &[0]);
        let o = b.div(e, rb);
        let module = Module::new("d", b.finish(o));
        let exe = compile_and_lower(&module, FusionMode::FusionStitching);
        let text = exe.disasm();
        assert!(text.contains("reduce.Sum"), "{text}");
        assert!(text.contains("-> output"), "{text}");
    }
}
