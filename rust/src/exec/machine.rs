//! The stitched VM: executes lowered bytecode with an explicit grid.
//!
//! A [`StitchedExecutable`] runs a whole compiled module as **one
//! launch per fused group** (plus one per library call), recording a
//! [`LaunchLedger`]. Each kernel launch iterates its grid: per block,
//! the block's shared-memory regions are materialized as buffers; per
//! stitched loop, a thread loop strides the block's chunk computing one
//! output element per [`ThreadProg`] evaluation.
//!
//! The VM deliberately enforces the stitching invariants instead of
//! papering over them:
//!
//! - a [`TInstr::LoadShared`] whose mapped index falls outside the
//!   executing block's chunk of the owner is an error (schedule
//!   propagation should have made chunks line up — §4.2);
//! - a shared region read while a different op owns it is an error (the
//!   §5.1.3 dominance rule should have prevented the reuse);
//! - kernel outputs only ever come from fusion roots — in-group
//!   consumers recompute or read shared memory, never global output
//!   written in the same launch (no *implicit* cross-block
//!   synchronization);
//! - the one sanctioned exception is the global stitching tier: a
//!   spilled intermediate ([`WriteTarget::Spill`]) is readable only
//!   after the [`BlockStep::GridFence`] that follows its producer. The
//!   VM splits the step list into phases at fences and joins every
//!   block between phases, so post-fence reads see every block's
//!   writes — the `grid.sync` model of a cooperative launch.

use super::bytecode::{
    chunk_index, chunk_index_into, chunk_offset, linearize, sched_blocks, sched_chunk,
    sched_linearize, BlockStep, KernelProgram, LoopKind, ShmRegion, StitchTier, TInstr,
    ThreadProg, WriteTarget, CONST_FILL,
};
use super::ledger::LaunchLedger;
use super::memplan::{BufSlot, MemoryPlan};
use crate::hlo::instruction::ReduceKind;
use crate::hlo::InstrId;
use anyhow::{anyhow, bail};
use std::collections::HashMap;

/// One entry parameter of the executable.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub id: InstrId,
    pub name: String,
    pub elems: usize,
}

/// A flat-buffer read: the resolved source instruction and the dims the
/// reader sees (bitcast aliases resolved at lowering). `slot` is the
/// source's arena range, baked by the memory planner.
#[derive(Debug, Clone, PartialEq)]
pub struct BufRead {
    pub src: InstrId,
    pub dims: Vec<i64>,
    pub slot: Option<BufSlot>,
}

/// A vendor-library launch (cuBLAS/cuDNN class — LC-layer ops).
#[derive(Debug, Clone, PartialEq)]
pub enum LibKind {
    /// `[..., m, k] x [..., k, n] -> [..., m, n]`, k ascending.
    Dot { lhs: BufRead, rhs: BufRead },
    /// NHWC input, HWIO filter, stride 1, SAME padding.
    Conv2d { input: BufRead, filter: BufRead },
}

#[derive(Debug, Clone, PartialEq)]
pub struct LibraryCall {
    pub op: InstrId,
    pub out_dims: Vec<i64>,
    pub out_elems: usize,
    pub kind: LibKind,
    /// The output's arena range, baked by the memory planner.
    pub out_slot: Option<BufSlot>,
}

/// One launch of the compiled module.
#[derive(Debug, Clone, PartialEq)]
pub enum Launch {
    Kernel(KernelProgram),
    Library(LibraryCall),
}

/// A whole lowered module, ready to run: the compiler's executable
/// artifact. Launches are in dependency (topological group) order.
#[derive(Debug, Clone, PartialEq)]
pub struct StitchedExecutable {
    pub name: String,
    /// Entry parameters in parameter-number order.
    pub params: Vec<ParamSpec>,
    /// Valueless IR constants, materialized as `CONST_FILL`.
    pub consts: Vec<(InstrId, usize)>,
    pub launches: Vec<Launch>,
    /// Buffer holding the module's result (bitcasts resolved).
    pub root: InstrId,
    pub root_elems: usize,
    /// Size of the value arena (instruction count of the source module).
    pub n_values: usize,
    /// The static buffer assignment: one flat-arena range per
    /// materialized value, lifetime-disjoint ranges reused
    /// ([`crate::exec::memplan`]).
    pub mem: MemoryPlan,
}

impl StitchedExecutable {
    /// Generated-kernel launches per execution (the Fig. 7 count).
    pub fn generated_launches(&self) -> u64 {
        self.launches.iter().filter(|l| matches!(l, Launch::Kernel(_))).count() as u64
    }

    /// Library-call launches per execution.
    pub fn library_launches(&self) -> u64 {
        self.launches.iter().filter(|l| matches!(l, Launch::Library(_))).count() as u64
    }

    /// Disassembly of every kernel launch (diagnostics / tests).
    pub fn disasm(&self) -> String {
        let mut out = String::new();
        for launch in &self.launches {
            match launch {
                Launch::Kernel(k) => out.push_str(&k.disasm()),
                Launch::Library(l) => {
                    let kind = match l.kind {
                        LibKind::Dot { .. } => "dot",
                        LibKind::Conv2d { .. } => "conv2d",
                    };
                    out.push_str(&format!("library %{} {}\n", l.op.0, kind));
                }
            }
        }
        out
    }

    /// Execute with one flattened f32 buffer per parameter; returns the
    /// module result and the launch ledger of this run. Convenience
    /// wrapper over [`StitchedExecutable::run_into`] with a throwaway
    /// arena — serving paths keep a pooled [`ExecArena`] instead so
    /// steady-state runs allocate nothing.
    pub fn run(&self, inputs: &[Vec<f32>]) -> crate::Result<(Vec<f32>, LaunchLedger)> {
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let mut arena = ExecArena::default();
        let mut out = Vec::new();
        let ledger = self.run_into(&refs, &mut arena, &mut out)?;
        Ok((out, ledger))
    }

    /// The fast execute path: memory-planned, specialized,
    /// block-parallel. Inputs are written into the pooled arena exactly
    /// once; every intermediate lives at its planned arena range; the
    /// grid loop of each launch fans out over the arena's VM threads
    /// when the launch is big enough to pay for it. The result lands in
    /// `out` (cleared and reused). Outputs and the launch ledger are
    /// bit-identical to [`StitchedExecutable::run_boxed`] at any thread
    /// count — the corpus-wide differential suite gates on it.
    pub fn run_into(
        &self,
        inputs: &[&[f32]],
        arena: &mut ExecArena,
        out: &mut Vec<f32>,
    ) -> crate::Result<LaunchLedger> {
        if inputs.len() != self.params.len() {
            bail!("{}: expected {} inputs, got {}", self.name, self.params.len(), inputs.len());
        }
        for (spec, buf) in self.params.iter().zip(inputs) {
            if buf.len() != spec.elems {
                bail!(
                    "{}: parameter {} expects {} elements, got {}",
                    self.name,
                    spec.name,
                    spec.elems,
                    buf.len()
                );
            }
        }
        if arena.data.len() < self.mem.arena_elems {
            arena.data.resize(self.mem.arena_elems, 0.0);
            arena.grows += 1;
        } else {
            arena.reuses += 1;
        }
        let threads = arena.resolved_threads();
        // Inputs are written into the arena exactly once per run — no
        // per-parameter clone, no re-copy downstream.
        for (spec, buf) in self.params.iter().zip(inputs) {
            if let Some(slot) = self.mem.slots[spec.id.0] {
                arena.data[slot.off..slot.off + buf.len()].copy_from_slice(buf);
            }
        }
        for &(id, elems) in &self.consts {
            if let Some(slot) = self.mem.slots[id.0] {
                arena.data[slot.off..slot.off + elems.max(1)].fill(CONST_FILL);
            }
        }

        let mut ledger = LaunchLedger::default();
        let ExecArena { data, scratch, .. } = arena;
        for launch in &self.launches {
            match launch {
                Launch::Kernel(k) => {
                    let span = crate::obs::begin();
                    let before = ledger;
                    run_kernel_fast(k, &self.mem, data, scratch, threads, &mut ledger)?;
                    ledger.generated += 1;
                    crate::obs::launch(
                        k.group_fp,
                        k.stitch_tier(),
                        k.modeled_us,
                        &ledger.since(&before),
                        span,
                    );
                }
                Launch::Library(l) => {
                    let span = crate::obs::begin();
                    run_library_fast(l, data)?;
                    ledger.library += 1;
                    crate::obs::record(crate::obs::SpanCat::Launch, "library", 0, span);
                }
            }
        }

        let root = self.mem.slots[self.root.0]
            .ok_or_else(|| anyhow!("{}: root value was never produced", self.name))?;
        out.clear();
        // `root_elems` is the true element count — the planner pads
        // zero-sized values to one arena element, and a degenerate
        // (0-element) root must still come back empty like the boxed
        // path's `vec![0f32; 0]`.
        out.extend_from_slice(&data[root.off..root.off + root.elems.min(self.root_elems)]);
        Ok(ledger)
    }

    /// The PR-2 reference path: every value in its own boxed buffer,
    /// tree-walking evaluation, single-threaded. Kept verbatim as the
    /// bit-identity baseline for the memory-planned VM (differential
    /// tests and `benches/vm_wallclock.rs` compare against it).
    pub fn run_boxed(&self, inputs: &[Vec<f32>]) -> crate::Result<(Vec<f32>, LaunchLedger)> {
        if inputs.len() != self.params.len() {
            bail!("{}: expected {} inputs, got {}", self.name, self.params.len(), inputs.len());
        }
        let mut values: Vec<Option<Vec<f32>>> = vec![None; self.n_values];
        for (spec, buf) in self.params.iter().zip(inputs) {
            if buf.len() != spec.elems {
                bail!(
                    "{}: parameter {} expects {} elements, got {}",
                    self.name,
                    spec.name,
                    spec.elems,
                    buf.len()
                );
            }
            values[spec.id.0] = Some(buf.clone());
        }
        for &(id, elems) in &self.consts {
            values[id.0] = Some(vec![CONST_FILL; elems.max(1)]);
        }

        let mut ledger = LaunchLedger::default();
        for launch in &self.launches {
            match launch {
                Launch::Kernel(k) => {
                    let span = crate::obs::begin();
                    let before = ledger;
                    run_kernel(k, &mut values, &mut ledger)?;
                    ledger.generated += 1;
                    crate::obs::launch(
                        k.group_fp,
                        k.stitch_tier(),
                        k.modeled_us,
                        &ledger.since(&before),
                        span,
                    );
                }
                Launch::Library(l) => {
                    let span = crate::obs::begin();
                    run_library(l, &mut values)?;
                    ledger.library += 1;
                    crate::obs::record(crate::obs::SpanCat::Launch, "library", 0, span);
                }
            }
        }

        let out = values[self.root.0]
            .clone()
            .ok_or_else(|| anyhow!("{}: root value was never produced", self.name))?;
        Ok((out, ledger))
    }
}

// ---------------------------------------------------------------------
// Pooled execution state (the fast path)
// ---------------------------------------------------------------------

/// Don't fan a launch out unless its total element work clears this —
/// scoped-thread startup costs tens of microseconds, which tiny
/// kernels cannot amortize.
const PAR_MIN_ELEMS: i64 = 16_384;

/// Pooled per-worker execution state: the flat value arena plus one
/// scratch set per VM thread. A serving worker keeps one `ExecArena`
/// for its lifetime; after the first run on a model the arena has
/// reached the plan's high-water mark and steady-state execution
/// performs **zero arena allocations** — `reuses()` counts exactly
/// those runs (surfaced in serving stats).
#[derive(Debug, Default)]
pub struct ExecArena {
    data: Vec<f32>,
    scratch: Vec<ThreadScratch>,
    /// VM thread cap; 0 = the process default
    /// ([`crate::exec::par::default_threads`]).
    threads: usize,
    grows: u64,
    reuses: u64,
}

impl ExecArena {
    pub fn new() -> Self {
        ExecArena::default()
    }

    /// An arena capped at `threads` VM threads (`0` = process default).
    /// A serving pool divides cores between its workers this way so
    /// shards times VM threads never oversubscribes the machine.
    pub fn with_threads(threads: usize) -> Self {
        ExecArena { threads, ..ExecArena::default() }
    }

    fn resolved_threads(&self) -> usize {
        if self.threads == 0 {
            super::par::default_threads()
        } else {
            self.threads
        }
    }

    /// Times the arena buffer had to grow (at most once per distinct
    /// plan size served by this arena).
    pub fn grows(&self) -> u64 {
        self.grows
    }

    /// Runs served entirely from resident memory — the steady-state
    /// counter behind the serving-path zero-allocation gate.
    pub fn reuses(&self) -> u64 {
        self.reuses
    }
}

/// Per-VM-thread scratch: the block's shared-memory buffer and owner
/// table, the chunk staging buffer, the register stack and reusable
/// index buffers. Everything grows to its high-water mark once and is
/// then reused across blocks, launches and runs.
#[derive(Debug, Default)]
struct ThreadScratch {
    shm: Vec<f32>,
    owners: Vec<Option<InstrId>>,
    vals: Vec<f32>,
    regs: Vec<f32>,
    pool: IdxPool,
    idx: Vec<i64>,
    idx_a: Vec<i64>,
    idx_b: Vec<i64>,
}

/// A checkout pool of index buffers for the (rare) non-affine paths
/// and `Branch` dispatch — recursion-safe, allocation-free once warm.
#[derive(Debug, Default)]
struct IdxPool {
    bufs: Vec<Vec<i64>>,
}

impl IdxPool {
    fn take(&mut self) -> Vec<i64> {
        self.bufs.pop().unwrap_or_default()
    }

    fn put(&mut self, buf: Vec<i64>) {
        self.bufs.push(buf);
    }
}

/// Raw-pointer view over the value arena, shared by the VM threads of
/// one launch.
///
/// SAFETY invariants (upheld by construction, tested by the
/// differential suite):
/// - concurrent blocks write *disjoint* element sets of each output
///   buffer (the chunk partition theorem — see
///   `chunk_partition_covers_every_element_once`);
/// - during a launch, reads target either values produced by earlier
///   launches (no writer this launch) or the executing block's own
///   chunk of a same-launch output (written by the same thread);
/// - all access goes through `get`/`set` (no `&`/`&mut` slices are
///   formed over concurrently-written memory).
#[derive(Clone, Copy)]
struct ArenaView<'a> {
    ptr: *mut f32,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut f32>,
}

unsafe impl Send for ArenaView<'_> {}
unsafe impl Sync for ArenaView<'_> {}

impl<'a> ArenaView<'a> {
    fn new(data: &'a mut [f32]) -> Self {
        ArenaView { ptr: data.as_mut_ptr(), len: data.len(), _marker: std::marker::PhantomData }
    }

    #[inline]
    fn get(&self, i: usize) -> f32 {
        assert!(i < self.len, "arena read out of range");
        unsafe { *self.ptr.add(i) }
    }

    #[inline]
    fn set(&self, i: usize, v: f32) {
        assert!(i < self.len, "arena write out of range");
        unsafe { *self.ptr.add(i) = v }
    }
}

/// Immutable per-block context for fast thread-program evaluation.
/// `'v` is the arena borrow (the whole launch), `'a` the per-step
/// borrows of the block's scratch.
struct FastCtx<'v, 'a> {
    view: &'a ArenaView<'v>,
    shm: &'a [f32],
    owners: &'a [Option<InstrId>],
    regions: &'a [ShmRegion],
    block: i64,
}

/// Per-block shared memory persisted across grid-fence phases: on a
/// real device a cooperative launch keeps every block resident across
/// `grid.sync`, so its shared buffer and region-owner table survive the
/// fence. Only global-tier kernels (rare) allocate these; single-phase
/// kernels reuse the pooled [`ThreadScratch`] buffers.
#[derive(Debug, Default)]
struct BlockShm {
    shm: Vec<f32>,
    owners: Vec<Option<InstrId>>,
}

/// Split a kernel's step list at grid fences: each [`BlockStep::GridFence`]
/// begins the phase it gates (the fence is the phase's first step, so
/// executing a phase counts its fence once per block), and the join
/// between phases realizes the fence's grid-wide ordering.
fn split_phases(steps: &[BlockStep]) -> Vec<&[BlockStep]> {
    let mut phases = Vec::new();
    let mut start = 0usize;
    for (i, s) in steps.iter().enumerate() {
        if matches!(s, BlockStep::GridFence) && i > start {
            phases.push(&steps[start..i]);
            start = i;
        }
    }
    phases.push(&steps[start..]);
    phases
}

fn run_kernel_fast(
    k: &KernelProgram,
    mem: &MemoryPlan,
    data: &mut Vec<f32>,
    scratch: &mut Vec<ThreadScratch>,
    max_threads: usize,
    ledger: &mut LaunchLedger,
) -> crate::Result<()> {
    // Fresh zeroed outputs, matching the boxed path's per-run
    // allocation (arena reuse may leave stale bytes behind).
    for &(root, _) in &k.outputs {
        let slot = mem.slots[root.0]
            .ok_or_else(|| anyhow!("output %{} has no arena slot", root.0))?;
        data[slot.off..slot.off + slot.elems].fill(0.0);
    }
    // Spill regions too — the global tier's intermediates live in the
    // arena under the same liveness discipline as outputs.
    for &(id, _) in &k.spills {
        let slot = mem.slots[id.0]
            .ok_or_else(|| anyhow!("spill %{} has no arena slot", id.0))?;
        data[slot.off..slot.off + slot.elems].fill(0.0);
    }
    match k.stitch_tier() {
        StitchTier::Global => ledger.tier_global += 1,
        StitchTier::Shm => ledger.tier_shm += 1,
        StitchTier::Plain => ledger.tier_plain += 1,
    }
    let blocks = k.blocks.max(1) as i64;
    ledger.block_iters += blocks as u64;
    let per_block: i64 = k
        .steps
        .iter()
        .map(|s| match s {
            BlockStep::Loop { dims, sched, .. } => sched_chunk(*sched, dims),
            BlockStep::Barrier | BlockStep::GridFence => 0,
        })
        .sum();
    let shm_elems = k.shm_regions.iter().map(|r| r.base + r.elems).max().unwrap_or(0);
    let workers = if max_threads > 1
        && blocks > 1
        && per_block.saturating_mul(blocks) >= PAR_MIN_ELEMS
    {
        max_threads.min(blocks as usize)
    } else {
        1
    };
    while scratch.len() < workers {
        scratch.push(ThreadScratch::default());
    }
    for s in scratch[..workers].iter_mut() {
        if s.shm.len() < shm_elems {
            s.shm.resize(shm_elems, 0.0);
        }
    }
    let view = ArenaView::new(data);
    let phases = split_phases(&k.steps);
    if phases.len() == 1 {
        let results = super::par::fan_out(&mut scratch[..workers], |t, s| {
            let mut lg = LaunchLedger::default();
            for b in super::par::block_range(blocks, workers, t) {
                exec_block(k, mem, &view, b, s, &mut lg)?;
            }
            Ok::<LaunchLedger, anyhow::Error>(lg)
        });
        // Fold per-worker ledgers in worker order: u64 sums are
        // order-independent, so counts match the boxed path exactly; the
        // first error in worker (= block) order wins.
        for r in results {
            ledger.merge(&r?);
        }
        return Ok(());
    }
    // Global tier: the grid fence joins every block between phases, so
    // each block's shared memory and owner table must persist across
    // the boundary — one `BlockShm` per block, held by the worker that
    // owns the block (the block→worker map is a pure function of
    // `(blocks, workers)`, identical in every phase).
    let mut block_shms: Vec<Vec<BlockShm>> = (0..workers)
        .map(|t| {
            super::par::block_range(blocks, workers, t)
                .map(|_| BlockShm {
                    shm: vec![0.0; shm_elems],
                    owners: vec![None; k.shm_regions.len()],
                })
                .collect()
        })
        .collect();
    let mut pairs: Vec<(&mut ThreadScratch, &mut Vec<BlockShm>)> =
        scratch[..workers].iter_mut().zip(block_shms.iter_mut()).collect();
    for phase in &phases {
        let results = super::par::fan_out(&mut pairs, |t, pair| {
            let (s, shms) = pair;
            let mut lg = LaunchLedger::default();
            for (i, b) in super::par::block_range(blocks, workers, t).enumerate() {
                let blk = &mut shms[i];
                let ThreadScratch { vals, regs, pool, idx, idx_a, idx_b, .. } = &mut **s;
                exec_steps(
                    phase, k, mem, &view, b, &mut blk.shm, &mut blk.owners, vals, regs, pool,
                    idx, idx_a, idx_b, &mut lg,
                )?;
            }
            Ok::<LaunchLedger, anyhow::Error>(lg)
        });
        // The join of this fan-out IS the grid fence: no block enters
        // the next phase until every block has finished this one.
        for r in results {
            ledger.merge(&r?);
        }
    }
    Ok(())
}

/// Single-phase block execution over the pooled per-worker scratch —
/// the common (fence-free) path: shared memory and owners reset per
/// block and the whole step list runs as one phase.
fn exec_block(
    k: &KernelProgram,
    mem: &MemoryPlan,
    view: &ArenaView<'_>,
    b: i64,
    s: &mut ThreadScratch,
    lg: &mut LaunchLedger,
) -> crate::Result<()> {
    let ThreadScratch { shm, owners, vals, regs, pool, idx, idx_a, idx_b } = s;
    owners.clear();
    owners.resize(k.shm_regions.len(), None);
    exec_steps(&k.steps, k, mem, view, b, shm, owners, vals, regs, pool, idx, idx_a, idx_b, lg)
}

/// Run one phase's steps for one block. `shm`/`owners` belong to the
/// block (persisting across phases in the multi-phase path); the rest
/// is per-worker scratch.
#[allow(clippy::too_many_arguments)]
fn exec_steps(
    steps: &[BlockStep],
    k: &KernelProgram,
    mem: &MemoryPlan,
    view: &ArenaView<'_>,
    b: i64,
    shm: &mut [f32],
    owners: &mut [Option<InstrId>],
    vals: &mut Vec<f32>,
    regs: &mut Vec<f32>,
    pool: &mut IdxPool,
    idx: &mut Vec<i64>,
    idx_a: &mut Vec<i64>,
    idx_b: &mut Vec<i64>,
    lg: &mut LaunchLedger,
) -> crate::Result<()> {
    for step in steps {
        match step {
            BlockStep::Barrier => lg.barriers += 1,
            BlockStep::GridFence => lg.fences += 1,
            BlockStep::Loop { op, dims, sched, kind, write } => {
                let grid = sched_blocks(*sched, dims);
                if b >= grid {
                    continue; // guarded-off block for this loop
                }
                let chunk = sched_chunk(*sched, dims);
                match write {
                    WriteTarget::Shared { slot, .. } => {
                        // Stage the chunk, then publish region + owner
                        // atomically — an op whose region space-shares
                        // with an operand's must not see its own partial
                        // writes (same contract as the boxed path).
                        vals.clear();
                        vals.resize(chunk as usize, 0.0);
                        {
                            let ctx = FastCtx {
                                view,
                                shm: &*shm,
                                owners: &*owners,
                                regions: &k.shm_regions,
                                block: b,
                            };
                            for e in 0..chunk {
                                chunk_index_into(*sched, dims, b, e, idx);
                                vals[e as usize] =
                                    compute_element_fast(kind, idx, &ctx, regs, pool, idx_a, idx_b)
                                        .map_err(|err| {
                                            anyhow!("kernel {} %{}: {err}", k.name, op.0)
                                        })?;
                                lg.thread_elems += 1;
                            }
                        }
                        let region = k.shm_regions[*slot];
                        shm[region.base..region.base + chunk as usize]
                            .copy_from_slice(&vals[..chunk as usize]);
                        owners[*slot] = Some(*op);
                    }
                    WriteTarget::Output | WriteTarget::Spill => {
                        let out_slot = mem.slots[op.0]
                            .ok_or_else(|| anyhow!("output %{} not allocated", op.0))?;
                        let ctx = FastCtx {
                            view,
                            shm: &*shm,
                            owners: &*owners,
                            regions: &k.shm_regions,
                            block: b,
                        };
                        for e in 0..chunk {
                            chunk_index_into(*sched, dims, b, e, idx);
                            let v =
                                compute_element_fast(kind, idx, &ctx, regs, pool, idx_a, idx_b)
                                    .map_err(|err| anyhow!("kernel {} %{}: {err}", k.name, op.0))?;
                            lg.thread_elems += 1;
                            let lin = linearize(idx, dims) as usize;
                            view.set(out_slot.off + lin, v);
                        }
                    }
                }
            }
        }
    }
    Ok(())
}

fn compute_element_fast(
    kind: &LoopKind,
    idx: &[i64],
    ctx: &FastCtx<'_, '_>,
    regs: &mut Vec<f32>,
    pool: &mut IdxPool,
    idx_a: &mut Vec<i64>,
    idx_b: &mut Vec<i64>,
) -> crate::Result<f32> {
    match kind {
        LoopKind::Map { prog } => eval_prog_fast(prog, idx, ctx, regs, pool, 0),
        LoopKind::Reduce { kind, dims, in_dims, operand, kept, sizes } => {
            // Same input-index walk as the boxed path (kept dims take
            // the output index, reduced dims count up row-major, dims
            // ascending), but with an in-place odometer instead of a
            // per-step delinearize.
            idx_a.clear();
            idx_a.resize(in_dims.len(), 0);
            for (kdim, &d) in kept.iter().enumerate() {
                idx_a[d] = idx[kdim];
            }
            let n: i64 = sizes.iter().product::<i64>().max(1);
            let mut acc = reduce_init(*kind);
            for _ in 0..n {
                let v = eval_prog_fast(operand, idx_a, ctx, regs, pool, 0)?;
                acc = reduce_combine(*kind, acc, v);
                for j in (0..dims.len()).rev() {
                    let d = dims[j];
                    idx_a[d] += 1;
                    if idx_a[d] < sizes[j] {
                        break;
                    }
                    idx_a[d] = 0;
                }
            }
            Ok(reduce_finish(*kind, acc, n))
        }
        LoopKind::Dot { lhs, rhs, lhs_dims, rhs_dims } => {
            let r = idx.len();
            debug_assert!(r >= 2);
            let kk = lhs_dims[r - 1];
            debug_assert_eq!(kk, rhs_dims[r - 2]);
            idx_a.clear();
            idx_a.extend_from_slice(idx);
            idx_b.clear();
            idx_b.extend_from_slice(idx);
            let mut acc = 0f32;
            for kdim in 0..kk {
                idx_a[r - 1] = kdim;
                idx_b[r - 2] = kdim;
                acc += eval_prog_fast(lhs, idx_a, ctx, regs, pool, 0)?
                    * eval_prog_fast(rhs, idx_b, ctx, regs, pool, 0)?;
            }
            Ok(acc)
        }
    }
}

fn eval_prog_fast(
    prog: &ThreadProg,
    idx: &[i64],
    ctx: &FastCtx<'_, '_>,
    regs: &mut Vec<f32>,
    pool: &mut IdxPool,
    base: usize,
) -> crate::Result<f32> {
    let need = base + prog.n_regs.max(1) as usize;
    if regs.len() < need {
        regs.resize(need, 0.0);
    }
    for ins in &prog.code {
        match ins {
            TInstr::Const { dst, value } => regs[base + *dst as usize] = *value,
            TInstr::LoadGlobal { dst, src, dims, map, lin, buf } => {
                let slot = buf
                    .ok_or_else(|| anyhow!("load of %{} is unresolved (no memory plan)", src.0))?;
                let off = match lin {
                    Some(a) => {
                        let l = a.apply(idx);
                        debug_assert_eq!(
                            l,
                            linearize(&map.apply(idx), dims),
                            "affine load of %{} diverged from the interpreted map",
                            src.0
                        );
                        l
                    }
                    None => {
                        let mut j = pool.take();
                        let mut t = pool.take();
                        map.apply_into(idx, &mut j, &mut t);
                        let l = linearize(&j, dims);
                        pool.put(t);
                        pool.put(j);
                        l
                    }
                };
                if off < 0 || off as usize >= slot.elems {
                    bail!("%{}: offset {off} out of bounds for dims {dims:?}", src.0);
                }
                regs[base + *dst as usize] = ctx.view.get(slot.off + off as usize);
            }
            TInstr::LoadShared {
                dst,
                offset,
                owner,
                owner_dims,
                owner_sched,
                map,
                slot,
                chunk,
                sched_lin,
            } => {
                match ctx.owners[*slot] {
                    Some(h) if h == *owner => {}
                    Some(h) => bail!(
                        "shared region at offset {offset} holds %{} but %{} was expected \
                         (space-sharing violation)",
                        h.0,
                        owner.0
                    ),
                    None => {
                        bail!("shared region at offset {offset} read before any write")
                    }
                }
                let l = match sched_lin {
                    Some(a) => {
                        let l = a.apply(idx);
                        debug_assert_eq!(
                            l,
                            sched_linearize(owner_sched.sched_type, owner_dims, &map.apply(idx)),
                            "affine shared read of %{} diverged",
                            owner.0
                        );
                        l
                    }
                    None => {
                        let mut j = pool.take();
                        let mut t = pool.take();
                        map.apply_into(idx, &mut j, &mut t);
                        let l = sched_linearize(owner_sched.sched_type, owner_dims, &j);
                        pool.put(t);
                        pool.put(j);
                        l
                    }
                };
                let start = ctx.block * chunk;
                if l < start || l >= start + chunk {
                    bail!(
                        "block {} reads %{} outside its shared chunk \
                         (stitching invariant violated)",
                        ctx.block,
                        owner.0
                    );
                }
                let region = ctx.regions[*slot];
                regs[base + *dst as usize] = ctx.shm[region.base + (l - start) as usize];
            }
            TInstr::LoadOwned { dst, src, dims, owner_sched, map, chunk, lin, sched_lin, buf } => {
                let slot = buf
                    .ok_or_else(|| anyhow!("load of %{} is unresolved (no memory plan)", src.0))?;
                let (l_row, l_sched) = match (lin, sched_lin) {
                    (Some(a), Some(sa)) => {
                        let lr = a.apply(idx);
                        let ls = sa.apply(idx);
                        debug_assert_eq!(lr, linearize(&map.apply(idx), dims));
                        debug_assert_eq!(
                            ls,
                            sched_linearize(owner_sched.sched_type, dims, &map.apply(idx))
                        );
                        (lr, ls)
                    }
                    _ => {
                        let mut j = pool.take();
                        let mut t = pool.take();
                        map.apply_into(idx, &mut j, &mut t);
                        let lr = linearize(&j, dims);
                        let ls = sched_linearize(owner_sched.sched_type, dims, &j);
                        pool.put(t);
                        pool.put(j);
                        (lr, ls)
                    }
                };
                let start = ctx.block * chunk;
                if l_sched < start || l_sched >= start + chunk {
                    bail!(
                        "block {} reads root %{} outside its own chunk \
                         (no cross-block synchronization exists)",
                        ctx.block,
                        src.0
                    );
                }
                if l_row < 0 || l_row as usize >= slot.elems {
                    bail!("%{}: offset {l_row} out of bounds for dims {dims:?}", src.0);
                }
                regs[base + *dst as usize] = ctx.view.get(slot.off + l_row as usize);
            }
            TInstr::Unary { dst, a, op } => {
                regs[base + *dst as usize] = op.apply(regs[base + *a as usize]);
            }
            TInstr::Binary { dst, a, b, op } => {
                regs[base + *dst as usize] =
                    op.apply(regs[base + *a as usize], regs[base + *b as usize]);
            }
            TInstr::Select { dst, pred, on_true, on_false } => {
                regs[base + *dst as usize] = if regs[base + *pred as usize] != 0.0 {
                    regs[base + *on_true as usize]
                } else {
                    regs[base + *on_false as usize]
                };
            }
            TInstr::Branch { dst, map, dim, limits, cases } => {
                let mut j = pool.take();
                let mut t = pool.take();
                map.apply_into(idx, &mut j, &mut t);
                pool.put(t);
                let x = j[*dim];
                let mut case = None;
                let mut prev = 0i64;
                for (i, &l) in limits.iter().enumerate() {
                    if x < l {
                        case = Some((i, prev));
                        break;
                    }
                    prev = l;
                }
                let Some((ci, start)) = case else {
                    bail!("concat index {x} out of range {limits:?}")
                };
                j[*dim] = x - start;
                // Sub-program registers live above this frame, so the
                // shared register stack never reallocates per element.
                let sub =
                    eval_prog_fast(&cases[ci], &j, ctx, regs, pool, base + prog.n_regs as usize);
                pool.put(j);
                regs[base + *dst as usize] = sub?;
            }
        }
    }
    Ok(regs[base + prog.out as usize])
}

/// Split the arena into two read views and one write view with the
/// planner's guarantee (output disjoint from inputs) verified at
/// runtime — a violation is a planner bug and fails loudly.
fn split_read2_write1(
    data: &mut [f32],
    a: BufSlot,
    b: BufSlot,
    o: BufSlot,
) -> crate::Result<(&[f32], &[f32], &mut [f32])> {
    let disjoint =
        |x: BufSlot, y: BufSlot| x.off + x.elems <= y.off || y.off + y.elems <= x.off;
    if !disjoint(a, o) || !disjoint(b, o) {
        bail!("memory plan violation: library output range overlaps an input range");
    }
    let n = data.len();
    if a.off + a.elems > n || b.off + b.elems > n || o.off + o.elems > n {
        bail!("memory plan violation: range exceeds the arena");
    }
    // SAFETY: the output range is disjoint from both input ranges
    // (checked above), so the mutable slice never aliases the shared
    // ones; the inputs may alias each other, which is fine for shared
    // references. All ranges are in bounds (checked above).
    let ptr = data.as_mut_ptr();
    unsafe {
        Ok((
            std::slice::from_raw_parts(ptr.add(a.off), a.elems),
            std::slice::from_raw_parts(ptr.add(b.off), b.elems),
            std::slice::from_raw_parts_mut(ptr.add(o.off), o.elems),
        ))
    }
}

fn run_library_fast(l: &LibraryCall, data: &mut [f32]) -> crate::Result<()> {
    let out_slot = l
        .out_slot
        .ok_or_else(|| anyhow!("library %{} output is unresolved (no memory plan)", l.op.0))?;
    let unresolved =
        |r: &BufRead| anyhow!("library operand %{} is unresolved (no memory plan)", r.src.0);
    match &l.kind {
        LibKind::Dot { lhs, rhs } => {
            let a = lhs.slot.ok_or_else(|| unresolved(lhs))?;
            let b = rhs.slot.ok_or_else(|| unresolved(rhs))?;
            let (av, bv, ov) = split_read2_write1(data, a, b, out_slot)?;
            ov.fill(0.0);
            dot_into(ov, av, &lhs.dims, bv, &rhs.dims, &l.out_dims);
        }
        LibKind::Conv2d { input, filter } => {
            let x = input.slot.ok_or_else(|| unresolved(input))?;
            let w = filter.slot.ok_or_else(|| unresolved(filter))?;
            let (xv, wv, ov) = split_read2_write1(data, x, w, out_slot)?;
            ov.fill(0.0);
            conv2d_same_into(ov, xv, &input.dims, wv, &filter.dims);
        }
    }
    Ok(())
}

/// Per-block evaluation context handed to thread programs.
struct EvalCtx<'a> {
    values: &'a [Option<Vec<f32>>],
    shm: &'a HashMap<usize, (InstrId, Vec<f32>)>,
    block: i64,
}

fn run_kernel(
    k: &KernelProgram,
    values: &mut [Option<Vec<f32>>],
    ledger: &mut LaunchLedger,
) -> crate::Result<()> {
    for &(root, elems) in &k.outputs {
        values[root.0] = Some(vec![0f32; elems]);
    }
    for &(id, elems) in &k.spills {
        values[id.0] = Some(vec![0f32; elems]);
    }
    match k.stitch_tier() {
        StitchTier::Global => ledger.tier_global += 1,
        StitchTier::Shm => ledger.tier_shm += 1,
        StitchTier::Plain => ledger.tier_plain += 1,
    }
    let threads = k.threads.max(1) as i64;
    let blocks = k.blocks.max(1) as i64;
    ledger.block_iters += blocks as u64;
    // Shared memory: byte-offset-keyed regions per block; a SHARE
    // rewrite replaces the previous owner (space sharing, §5.1.3).
    // The maps live outside the phase loop because shared memory
    // survives a grid fence — and phases run blocks-INNER: block 0's
    // post-fence phase may read spill elements written by every other
    // block's pre-fence phase.
    let mut shms: Vec<HashMap<usize, (InstrId, Vec<f32>)>> =
        (0..blocks).map(|_| HashMap::new()).collect();
    for phase in split_phases(&k.steps) {
        for b in 0..blocks {
            let shm = &mut shms[b as usize];
            for step in phase {
                match step {
                    BlockStep::Barrier => ledger.barriers += 1,
                    BlockStep::GridFence => ledger.fences += 1,
                    BlockStep::Loop { op, dims, sched, kind, write } => {
                        let grid = sched_blocks(*sched, dims);
                        if b >= grid {
                            continue; // guarded-off block for this loop
                        }
                        let chunk = sched_chunk(*sched, dims);
                        let mut vals = vec![0f32; chunk as usize];
                        {
                            let ctx = EvalCtx { values: &values[..], shm: &*shm, block: b };
                            for t in 0..threads {
                                let mut e = t;
                                while e < chunk {
                                    let idx = chunk_index(*sched, dims, b, e);
                                    vals[e as usize] = compute_element(kind, &idx, &ctx)
                                        .map_err(|err| {
                                            anyhow!("kernel {} %{}: {err}", k.name, op.0)
                                        })?;
                                    ledger.thread_elems += 1;
                                    e += threads;
                                }
                            }
                        }
                        match write {
                            WriteTarget::Shared { offset, .. } => {
                                shm.insert(*offset, (*op, vals));
                            }
                            WriteTarget::Output | WriteTarget::Spill => {
                                let buf = values[op.0]
                                    .as_mut()
                                    .ok_or_else(|| anyhow!("output %{} not allocated", op.0))?;
                                for e in 0..chunk {
                                    let idx = chunk_index(*sched, dims, b, e);
                                    let lin = linearize(&idx, dims) as usize;
                                    buf[lin] = vals[e as usize];
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    Ok(())
}

fn compute_element(kind: &LoopKind, idx: &[i64], ctx: &EvalCtx<'_>) -> crate::Result<f32> {
    match kind {
        LoopKind::Map { prog } => eval_prog(prog, idx, ctx),
        LoopKind::Reduce { kind, dims, in_dims, operand, .. } => {
            // Rebuild the input index: kept dims take the output index,
            // reduced dims iterate row-major (dims ascending) — the same
            // order the op-by-op interpreter uses, so accumulation is
            // bit-identical.
            let kept: Vec<usize> = (0..in_dims.len()).filter(|d| !dims.contains(d)).collect();
            let mut in_idx = vec![0i64; in_dims.len()];
            for (k, &d) in kept.iter().enumerate() {
                in_idx[d] = idx[k];
            }
            let sizes: Vec<i64> = dims.iter().map(|&d| in_dims[d]).collect();
            let n: i64 = sizes.iter().product::<i64>().max(1);
            let mut acc = reduce_init(*kind);
            for it in 0..n {
                let sub = super::bytecode::delinearize(it, &sizes);
                for (j, &d) in dims.iter().enumerate() {
                    in_idx[d] = sub[j];
                }
                let v = eval_prog(operand, &in_idx, ctx)?;
                acc = reduce_combine(*kind, acc, v);
            }
            Ok(reduce_finish(*kind, acc, n))
        }
        LoopKind::Dot { lhs, rhs, lhs_dims, rhs_dims } => {
            let r = idx.len();
            debug_assert!(r >= 2);
            let kk = lhs_dims[r - 1];
            debug_assert_eq!(kk, rhs_dims[r - 2]);
            let mut lhs_idx = idx.to_vec();
            let mut rhs_idx = idx.to_vec();
            let mut acc = 0f32;
            for k in 0..kk {
                lhs_idx[r - 1] = k;
                rhs_idx[r - 2] = k;
                acc += eval_prog(lhs, &lhs_idx, ctx)? * eval_prog(rhs, &rhs_idx, ctx)?;
            }
            Ok(acc)
        }
    }
}

pub(crate) fn reduce_init(kind: ReduceKind) -> f32 {
    match kind {
        ReduceKind::Sum | ReduceKind::Mean => 0.0,
        ReduceKind::Max => f32::NEG_INFINITY,
        ReduceKind::Min => f32::INFINITY,
        ReduceKind::Prod => 1.0,
    }
}

pub(crate) fn reduce_combine(kind: ReduceKind, acc: f32, v: f32) -> f32 {
    match kind {
        ReduceKind::Sum | ReduceKind::Mean => acc + v,
        ReduceKind::Max => acc.max(v),
        ReduceKind::Min => acc.min(v),
        ReduceKind::Prod => acc * v,
    }
}

pub(crate) fn reduce_finish(kind: ReduceKind, acc: f32, n: i64) -> f32 {
    match kind {
        ReduceKind::Mean => acc / n as f32,
        _ => acc,
    }
}

fn eval_prog(prog: &ThreadProg, idx: &[i64], ctx: &EvalCtx<'_>) -> crate::Result<f32> {
    let mut regs = vec![0f32; prog.n_regs.max(1) as usize];
    for ins in &prog.code {
        match ins {
            TInstr::Const { dst, value } => regs[*dst as usize] = *value,
            TInstr::LoadGlobal { dst, src, dims, map, .. } => {
                let j = map.apply(idx);
                let lin = linearize(&j, dims);
                let buf = ctx.values[src.0]
                    .as_ref()
                    .ok_or_else(|| anyhow!("value %{} read before it was produced", src.0))?;
                regs[*dst as usize] = *buf.get(lin as usize).ok_or_else(|| {
                    anyhow!("%{}: index {j:?} out of bounds for dims {dims:?}", src.0)
                })?;
            }
            TInstr::LoadShared { dst, offset, owner, owner_dims, owner_sched, map, .. } => {
                let j = map.apply(idx);
                let (holder, buf) = ctx.shm.get(offset).ok_or_else(|| {
                    anyhow!("shared region at offset {offset} read before any write")
                })?;
                if holder != owner {
                    bail!(
                        "shared region at offset {offset} holds %{} but %{} was expected \
                         (space-sharing violation)",
                        holder.0,
                        owner.0
                    );
                }
                let local = chunk_offset(*owner_sched, owner_dims, ctx.block, &j).ok_or_else(
                    || {
                        anyhow!(
                            "block {} reads %{} at {j:?}, outside its shared chunk \
                             (stitching invariant violated)",
                            ctx.block,
                            owner.0
                        )
                    },
                )?;
                regs[*dst as usize] = buf[local as usize];
            }
            TInstr::LoadOwned { dst, src, dims, owner_sched, map, .. } => {
                let j = map.apply(idx);
                if chunk_offset(*owner_sched, dims, ctx.block, &j).is_none() {
                    bail!(
                        "block {} reads root %{} at {j:?}, outside its own chunk \
                         (no cross-block synchronization exists)",
                        ctx.block,
                        src.0
                    );
                }
                let lin = linearize(&j, dims) as usize;
                let buf = ctx.values[src.0]
                    .as_ref()
                    .ok_or_else(|| anyhow!("root %{} output not allocated", src.0))?;
                regs[*dst as usize] = buf[lin];
            }
            TInstr::Unary { dst, a, op } => {
                regs[*dst as usize] = op.apply(regs[*a as usize]);
            }
            TInstr::Binary { dst, a, b, op } => {
                regs[*dst as usize] = op.apply(regs[*a as usize], regs[*b as usize]);
            }
            TInstr::Select { dst, pred, on_true, on_false } => {
                regs[*dst as usize] = if regs[*pred as usize] != 0.0 {
                    regs[*on_true as usize]
                } else {
                    regs[*on_false as usize]
                };
            }
            TInstr::Branch { dst, map, dim, limits, cases } => {
                let mut j = map.apply(idx);
                let x = j[*dim];
                let mut case = None;
                let mut prev = 0i64;
                for (i, &l) in limits.iter().enumerate() {
                    if x < l {
                        case = Some((i, prev));
                        break;
                    }
                    prev = l;
                }
                let (ci, start) =
                    case.ok_or_else(|| anyhow!("concat index {x} out of range {limits:?}"))?;
                j[*dim] = x - start;
                regs[*dst as usize] = eval_prog(&cases[ci], &j, ctx)?;
            }
        }
    }
    Ok(regs[prog.out as usize])
}

fn read_buf<'a>(
    values: &'a [Option<Vec<f32>>],
    r: &BufRead,
) -> crate::Result<&'a [f32]> {
    values[r.src.0]
        .as_deref()
        .ok_or_else(|| anyhow!("library operand %{} not yet produced", r.src.0))
}

fn run_library(l: &LibraryCall, values: &mut [Option<Vec<f32>>]) -> crate::Result<()> {
    let out = match &l.kind {
        LibKind::Dot { lhs, rhs } => {
            let a = read_buf(&values[..], lhs)?;
            let b = read_buf(&values[..], rhs)?;
            dot(a, &lhs.dims, b, &rhs.dims, &l.out_dims)
        }
        LibKind::Conv2d { input, filter } => {
            let x = read_buf(&values[..], input)?;
            let w = read_buf(&values[..], filter)?;
            conv2d_same(x, &input.dims, w, &filter.dims, &l.out_dims)
        }
    };
    values[l.op.0] = Some(out);
    Ok(())
}

/// Batched matmul `[..., m, k] x [..., k, n] -> [..., m, n]`; the exact
/// accumulation order (k ascending per output element) is shared with
/// the interpreter so results are bit-identical.
pub(crate) fn dot(
    a: &[f32],
    a_dims: &[i64],
    b: &[f32],
    b_dims: &[i64],
    out_dims: &[i64],
) -> Vec<f32> {
    let r = out_dims.len();
    let batch: i64 = out_dims[..r - 2].iter().product::<i64>().max(1);
    let mut out = vec![0f32; (batch * out_dims[r - 2] * out_dims[r - 1]) as usize];
    dot_into(&mut out, a, a_dims, b, b_dims, out_dims);
    out
}

/// [`dot`] into a pre-zeroed output slice, cache-blocked: the loops run
/// i-k-j so the inner loop streams one row of `b` and one row of `out`
/// at unit stride (instead of striding `b` by `n` per term). Each
/// `out[i, j]` still receives its `k` terms in ascending order starting
/// from `0.0`, so the float addition sequence — and therefore the bits
/// — match the naive j-inner form exactly (asserted by
/// `dot_blocked_is_bit_identical_to_naive`).
pub(crate) fn dot_into(
    out: &mut [f32],
    a: &[f32],
    a_dims: &[i64],
    b: &[f32],
    b_dims: &[i64],
    out_dims: &[i64],
) {
    let r = out_dims.len();
    let batch: i64 = out_dims[..r - 2].iter().product::<i64>().max(1);
    let m = out_dims[r - 2] as usize;
    let n = out_dims[r - 1] as usize;
    let k = a_dims[r - 1] as usize;
    debug_assert_eq!(a_dims[r - 1], b_dims[r - 2]);
    for bi in 0..batch as usize {
        let ao = bi * m * k;
        let bo = bi * k * n;
        let oo = bi * m * n;
        for i in 0..m {
            let arow = &a[ao + i * k..ao + (i + 1) * k];
            let orow = &mut out[oo + i * n..oo + (i + 1) * n];
            for (kk, &av) in arow.iter().enumerate() {
                let brow = &b[bo + kk * n..bo + (kk + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    }
}

/// NHWC x HWIO convolution, stride 1, SAME padding (zero fill), the
/// shape contract of [`crate::hlo::GraphBuilder::conv2d`].
pub(crate) fn conv2d_same(
    x: &[f32],
    x_dims: &[i64],
    w: &[f32],
    w_dims: &[i64],
    out_dims: &[i64],
) -> Vec<f32> {
    let mut out = vec![0f32; out_dims.iter().product::<i64>() as usize];
    conv2d_same_into(&mut out, x, x_dims, w, w_dims);
    out
}

/// [`conv2d_same`] into an output slice, with the invariant index
/// arithmetic hoisted out of the channel loop: the input row base and
/// the filter tap base are computed once per `(kh, kw)` tap instead of
/// re-deriving `(((khi*kw + kwi)*c + ci2)*co + oi)` per channel. The
/// loop nesting and every float operation (including the `0.0 * w`
/// products of zero-padded taps) are unchanged, so outputs are
/// bit-identical to the naive form (asserted by
/// `conv2d_hoisted_is_bit_identical_to_naive`).
pub(crate) fn conv2d_same_into(
    out: &mut [f32],
    x: &[f32],
    x_dims: &[i64],
    w: &[f32],
    w_dims: &[i64],
) {
    let (n, h, wd, c) = (x_dims[0], x_dims[1], x_dims[2], x_dims[3]);
    let (kh, kw, _ci, co) = (w_dims[0], w_dims[1], w_dims[2], w_dims[3]);
    let pad_h = (kh - 1) / 2;
    let pad_w = (kw - 1) / 2;
    let (c_u, co_u) = (c as usize, co as usize);
    let mut o = 0usize;
    for ni in 0..n {
        for hi in 0..h {
            for wi in 0..wd {
                for oi in 0..co {
                    let mut acc = 0f32;
                    for khi in 0..kh {
                        let ih = hi + khi - pad_h;
                        let row_ok = ih >= 0 && ih < h;
                        let x_row = ((ni * h + ih) * wd) * c;
                        let w_row = khi * kw;
                        for kwi in 0..kw {
                            let iw = wi + kwi - pad_w;
                            // filter tap base: w[w_tap + ci2 * co]
                            let w_tap = ((w_row + kwi) * c * co + oi) as usize;
                            if row_ok && iw >= 0 && iw < wd {
                                let xb = (x_row + iw * c) as usize;
                                for ci2 in 0..c_u {
                                    acc += x[xb + ci2] * w[w_tap + ci2 * co_u];
                                }
                            } else {
                                // Zero-padded tap: keep the 0.0 * w
                                // products so NaN/Inf filters propagate
                                // exactly as in the naive form.
                                for ci2 in 0..c_u {
                                    acc += 0.0 * w[w_tap + ci2 * co_u];
                                }
                            }
                        }
                    }
                    out[o] = acc;
                    o += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::{compile_module, FusionMode, PipelineConfig};
    use crate::exec::lower::lower_to_exec;
    use crate::gpusim::DeviceConfig;
    use crate::hlo::instruction::ReduceKind;
    use crate::hlo::{GraphBuilder, Module, Shape};
    use crate::schedule::PerfLibrary;

    fn compile_and_lower(module: &Module, mode: FusionMode) -> StitchedExecutable {
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let cfg = PipelineConfig::default();
        let compiled = compile_module(module, mode, &mut lib, &cfg).unwrap();
        lower_to_exec(module, &compiled.plan, &compiled.kernels, &compiled.generated_group_ids)
            .unwrap()
    }

    fn fill(n: usize, seed: u64) -> Vec<f32> {
        (0..n)
            .map(|i| {
                let h = (i as u64).wrapping_mul(2654435761).wrapping_add(seed * 97);
                ((h % 1000) as f32) / 1000.0 - 0.5
            })
            .collect()
    }

    /// Reference softmax(scores) @ v over the last dim of [b, s, s].
    fn softmax_bmm_ref(scores: &[f32], v: &[f32], b: usize, s: usize, d: usize) -> Vec<f32> {
        let mut out = vec![0f32; b * s * d];
        for bi in 0..b {
            for i in 0..s {
                let row = &scores[bi * s * s + i * s..bi * s * s + (i + 1) * s];
                let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let e: Vec<f32> = row.iter().map(|&x| (x - m).exp()).collect();
                let sum: f32 = e.iter().sum();
                for j in 0..d {
                    let mut acc = 0f32;
                    for kk in 0..s {
                        acc += (e[kk] / sum) * v[bi * s * d + kk * d + j];
                    }
                    out[bi * s * d + i * d + j] = acc;
                }
            }
        }
        out
    }

    #[test]
    fn figure3_kernel_executes_softmax_bmm() {
        // The paper's motivating pattern as ONE launch.
        let (bs, s, d) = (4usize, 16usize, 8usize);
        let mut b = GraphBuilder::new("fig3");
        let scores = b.param("scores", Shape::f32(&[bs as i64, s as i64, s as i64]));
        let v = b.param("v", Shape::f32(&[bs as i64, s as i64, d as i64]));
        let m = b.reduce(scores, &[2], ReduceKind::Max);
        let mb = b.broadcast(m, &[bs as i64, s as i64, s as i64], &[0, 1]);
        let sh = b.sub(scores, mb);
        let e = b.exp(sh);
        let sm = b.reduce(e, &[2], ReduceKind::Sum);
        let sb = b.broadcast(sm, &[bs as i64, s as i64, s as i64], &[0, 1]);
        let p = b.div(e, sb);
        let out = b.batch_dot(p, v);
        let module = Module::new("fig3", b.finish(out));

        let mut cfg = PipelineConfig::default();
        cfg.deep.fuse_batch_dot = true;
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let compiled =
            compile_module(&module, FusionMode::FusionStitching, &mut lib, &cfg).unwrap();
        let exe = lower_to_exec(
            &module,
            &compiled.plan,
            &compiled.kernels,
            &compiled.generated_group_ids,
        )
        .unwrap();

        let scores_v = fill(bs * s * s, 1);
        let v_v = fill(bs * s * d, 2);
        let (got, ledger) = exe.run(&[scores_v.clone(), v_v.clone()]).unwrap();
        let want = softmax_bmm_ref(&scores_v, &v_v, bs, s, d);
        let max_diff = got
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_diff < 1e-5, "stitched softmax-bmm diverged: {max_diff}");
        // With batch-dot fusion on, the whole pattern is few launches —
        // far fewer than the 8 per-op kernels.
        assert!(ledger.total_launches() < 8, "{ledger}");
        assert!(ledger.generated >= 1);
        assert!(ledger.barriers > 0, "shared-memory stitching must fence: {ledger}");
    }

    #[test]
    fn baseline_and_stitched_agree_on_elementwise_chain() {
        let mut b = GraphBuilder::new("chain");
        let x = b.param("x", Shape::f32(&[32, 24]));
        let y = b.param("y", Shape::f32(&[32, 24]));
        let e = b.exp(x);
        let a = b.add(e, y);
        let t = b.tanh(a);
        let g = b.compare(t, y);
        let sel = b.select(g, t, y);
        let r = b.reduce(sel, &[1], ReduceKind::Mean);
        let module = Module::new("chain", b.finish(r));

        let base = compile_and_lower(&module, FusionMode::XlaBaseline);
        let fs = compile_and_lower(&module, FusionMode::FusionStitching);
        let xs = fill(32 * 24, 3);
        let ys = fill(32 * 24, 4);
        let (ob, lb) = base.run(&[xs.clone(), ys.clone()]).unwrap();
        let (of, lf) = fs.run(&[xs, ys]).unwrap();
        assert_eq!(ob.len(), 32);
        let max_diff =
            ob.iter().zip(&of).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
        assert!(max_diff < 1e-5, "modes diverged: {max_diff}");
        assert!(
            lf.total_launches() <= lb.total_launches(),
            "deep fusion must not launch more: {lf} vs {lb}"
        );
    }

    #[test]
    fn library_dot_and_conv_execute() {
        let mut b = GraphBuilder::new("lib");
        let x = b.param("x", Shape::f32(&[2, 3]));
        let w = b.param("w", Shape::f32(&[3, 2]));
        let d = b.dot(x, w);
        let t = b.tanh(d);
        let module = Module::new("lib", b.finish(t));
        let exe = compile_and_lower(&module, FusionMode::FusionStitching);
        let (out, ledger) = exe
            .run(&[vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]])
            .unwrap();
        // row0: [1,2,3] x cols [1,0,1]^T etc: [1*1+2*0+3*1, 1*0+2*1+3*1] = [4, 5]
        assert!((out[0] - (4.0f32).tanh()).abs() < 1e-6);
        assert!((out[1] - (5.0f32).tanh()).abs() < 1e-6);
        assert_eq!(ledger.library, 1);
        assert!(ledger.generated >= 1);
    }

    /// The pre-PR j-inner matmul, transcribed verbatim: the bitwise
    /// reference for the cache-blocked [`dot_into`].
    fn dot_naive(a: &[f32], a_dims: &[i64], b: &[f32], b_dims: &[i64], out_dims: &[i64]) -> Vec<f32> {
        let r = out_dims.len();
        let batch: i64 = out_dims[..r - 2].iter().product::<i64>().max(1);
        let m = out_dims[r - 2];
        let n = out_dims[r - 1];
        let k = a_dims[r - 1];
        assert_eq!(k, b_dims[r - 2]);
        let mut out = vec![0f32; (batch * m * n) as usize];
        for bi in 0..batch {
            let ao = (bi * m * k) as usize;
            let bo = (bi * k * n) as usize;
            let oo = (bi * m * n) as usize;
            for i in 0..m as usize {
                for j in 0..n as usize {
                    let mut acc = 0f32;
                    for kk in 0..k as usize {
                        acc += a[ao + i * k as usize + kk] * b[bo + kk * n as usize + j];
                    }
                    out[oo + i * n as usize + j] = acc;
                }
            }
        }
        out
    }

    /// The pre-PR closure-per-tap convolution, transcribed verbatim:
    /// the bitwise reference for the hoisted [`conv2d_same_into`].
    fn conv2d_naive(x: &[f32], x_dims: &[i64], w: &[f32], w_dims: &[i64], out_dims: &[i64]) -> Vec<f32> {
        let (n, h, wd, c) = (x_dims[0], x_dims[1], x_dims[2], x_dims[3]);
        let (kh, kw, _ci, co) = (w_dims[0], w_dims[1], w_dims[2], w_dims[3]);
        let pad_h = (kh - 1) / 2;
        let pad_w = (kw - 1) / 2;
        let mut out = vec![0f32; out_dims.iter().product::<i64>() as usize];
        let xi = |ni: i64, hi: i64, wi: i64, ci2: i64| -> f32 {
            if hi < 0 || hi >= h || wi < 0 || wi >= wd {
                0.0
            } else {
                x[(((ni * h + hi) * wd + wi) * c + ci2) as usize]
            }
        };
        let mut o = 0usize;
        for ni in 0..n {
            for hi in 0..h {
                for wi in 0..wd {
                    for oi in 0..co {
                        let mut acc = 0f32;
                        for khi in 0..kh {
                            for kwi in 0..kw {
                                for ci2 in 0..c {
                                    let xv = xi(ni, hi + khi - pad_h, wi + kwi - pad_w, ci2);
                                    let wv = w[(((khi * kw + kwi) * c + ci2) * co + oi) as usize];
                                    acc += xv * wv;
                                }
                            }
                        }
                        out[o] = acc;
                        o += 1;
                    }
                }
            }
        }
        out
    }

    #[test]
    fn dot_blocked_is_bit_identical_to_naive() {
        for (batch, m, k, n, seed) in
            [(1i64, 7i64, 13i64, 9i64, 1u64), (3, 4, 17, 5, 2), (2, 1, 31, 1, 3), (1, 16, 16, 16, 4)]
        {
            let a = fill((batch * m * k) as usize, seed);
            let b = fill((batch * k * n) as usize, seed + 10);
            let a_dims = [batch, m, k];
            let b_dims = [batch, k, n];
            let out_dims = [batch, m, n];
            let fast = dot(&a, &a_dims, &b, &b_dims, &out_dims);
            let naive = dot_naive(&a, &a_dims, &b, &b_dims, &out_dims);
            assert_eq!(fast.len(), naive.len());
            for (i, (x, y)) in fast.iter().zip(&naive).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "element {i}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn conv2d_hoisted_is_bit_identical_to_naive() {
        for (n, h, wd, c, kh, kw, co, seed) in
            [(1i64, 5i64, 5i64, 3i64, 3i64, 3i64, 4i64, 1u64), (2, 7, 4, 2, 5, 3, 1, 2), (1, 1, 1, 1, 1, 1, 1, 3)]
        {
            let x = fill((n * h * wd * c) as usize, seed);
            let w = fill((kh * kw * c * co) as usize, seed + 7);
            let x_dims = [n, h, wd, c];
            let w_dims = [kh, kw, c, co];
            let out_dims = [n, h, wd, co];
            let fast = conv2d_same(&x, &x_dims, &w, &w_dims, &out_dims);
            let naive = conv2d_naive(&x, &x_dims, &w, &w_dims, &out_dims);
            assert_eq!(fast.len(), naive.len());
            for (i, (a, b)) in fast.iter().zip(&naive).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "element {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn fast_path_is_bit_identical_to_boxed_and_reuses_the_arena() {
        // The Fig. 3 pattern again — shared-memory stitching, barriers,
        // batch-dot — executed on the planned/parallel path vs the
        // boxed PR-2 reference, at a forced multi-thread count.
        let (bs, s, d) = (4usize, 16usize, 8usize);
        let mut b = GraphBuilder::new("fig3");
        let scores = b.param("scores", Shape::f32(&[bs as i64, s as i64, s as i64]));
        let v = b.param("v", Shape::f32(&[bs as i64, s as i64, d as i64]));
        let m = b.reduce(scores, &[2], ReduceKind::Max);
        let mb = b.broadcast(m, &[bs as i64, s as i64, s as i64], &[0, 1]);
        let sh = b.sub(scores, mb);
        let e = b.exp(sh);
        let sm = b.reduce(e, &[2], ReduceKind::Sum);
        let sb = b.broadcast(sm, &[bs as i64, s as i64, s as i64], &[0, 1]);
        let p = b.div(e, sb);
        let out = b.batch_dot(p, v);
        let module = Module::new("fig3", b.finish(out));
        let mut cfg = PipelineConfig::default();
        cfg.deep.fuse_batch_dot = true;
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let compiled =
            compile_module(&module, FusionMode::FusionStitching, &mut lib, &cfg).unwrap();
        let exe = lower_to_exec(
            &module,
            &compiled.plan,
            &compiled.kernels,
            &compiled.generated_group_ids,
        )
        .unwrap();

        let inputs = vec![fill(bs * s * s, 11), fill(bs * s * d, 12)];
        let (boxed_out, boxed_ledger) = exe.run_boxed(&inputs).unwrap();

        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let mut arena = ExecArena::with_threads(4);
        let mut fast_out = Vec::new();
        let fast_ledger = exe.run_into(&refs, &mut arena, &mut fast_out).unwrap();
        assert_eq!(fast_ledger, boxed_ledger, "launch ledger must be unchanged");
        assert_eq!(fast_out.len(), boxed_out.len());
        for (i, (a, b)) in fast_out.iter().zip(&boxed_out).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "element {i}: {a} vs {b}");
        }

        // Steady state: the pooled arena never grows again.
        assert_eq!(arena.grows(), 1);
        for _ in 0..3 {
            let l = exe.run_into(&refs, &mut arena, &mut fast_out).unwrap();
            assert_eq!(l, boxed_ledger);
        }
        assert_eq!(arena.grows(), 1, "steady-state runs must not allocate arena memory");
        assert_eq!(arena.reuses(), 3);
        // The plan actually packed values tighter than the boxed VM's
        // one-buffer-per-value layout.
        assert!(exe.mem.arena_elems <= exe.mem.total_value_elems);
    }

    #[test]
    fn conv2d_same_matches_manual() {
        // 1x3x3x1 input, 3x3x1x1 filter of ones: each output = sum of
        // the 3x3 neighborhood (zero padded).
        let x: Vec<f32> = (1..=9).map(|v| v as f32).collect();
        let w = vec![1.0f32; 9];
        let out = conv2d_same(&x, &[1, 3, 3, 1], &w, &[3, 3, 1, 1], &[1, 3, 3, 1]);
        // center = sum(1..9) = 45; corner (0,0) = 1+2+4+5 = 12
        assert_eq!(out[4], 45.0);
        assert_eq!(out[0], 12.0);
    }

    #[test]
    fn arity_and_size_checked() {
        let mut b = GraphBuilder::new("m");
        let x = b.param("x", Shape::f32(&[4]));
        let t = b.tanh(x);
        let module = Module::new("m", b.finish(t));
        let exe = compile_and_lower(&module, FusionMode::FusionStitching);
        assert!(exe.run(&[]).is_err());
        assert!(exe.run(&[vec![0.0; 3]]).is_err());
        assert!(exe.run(&[vec![0.0; 4]]).is_ok());
    }

    #[test]
    fn split_phases_fences_begin_phases() {
        let steps = vec![
            BlockStep::Barrier,
            BlockStep::GridFence,
            BlockStep::Barrier,
            BlockStep::GridFence,
            BlockStep::GridFence,
            BlockStep::Barrier,
        ];
        let phases = split_phases(&steps);
        assert_eq!(phases.len(), 4);
        assert_eq!(phases[0].len(), 1);
        for phase in &phases[1..] {
            assert!(matches!(phase[0], BlockStep::GridFence), "fence must begin its phase");
        }
        assert_eq!(phases.iter().map(|p| p.len()).sum::<usize>(), steps.len());
        // Fence-free step lists stay a single phase.
        assert_eq!(split_phases(&[BlockStep::Barrier]).len(), 1);
        assert_eq!(split_phases(&[]).len(), 1);
    }

    #[test]
    fn disasm_shows_loops_and_barriers() {
        let mut b = GraphBuilder::new("d");
        let x = b.param("x", Shape::f32(&[8, 32]));
        let e = b.exp(x);
        let r = b.reduce(e, &[1], ReduceKind::Sum);
        let rb = b.broadcast(r, &[8, 32], &[0]);
        let o = b.div(e, rb);
        let module = Module::new("d", b.finish(o));
        let exe = compile_and_lower(&module, FusionMode::FusionStitching);
        let text = exe.disasm();
        assert!(text.contains("reduce.Sum"), "{text}");
        assert!(text.contains("-> output"), "{text}");
    }
}
