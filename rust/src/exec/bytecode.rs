//! The stitched-kernel bytecode: what a [`crate::codegen::KernelPlan`]
//! lowers to and what the VM ([`super::machine`]) executes.
//!
//! One fused group becomes one [`KernelProgram`] — a single launch. The
//! program models the GPU grid explicitly:
//!
//! - the **block loop** runs every [`BlockStep`] once per thread block
//!   (grid size = the tuned `blocks`);
//! - each [`BlockStep::Loop`] is one stitched parallel loop (Algorithm
//!   2's `StitchedEmitter`): it walks the op's per-block chunk of its
//!   work space under the op's tuned [`Schedule`] with a **thread
//!   loop** striding by `threads`;
//! - per output element a [`ThreadProg`] runs — straight-line register
//!   bytecode with the elemental (thread-composed) producers inlined,
//!   shared-memory operands read from the block's shared regions and
//!   out-of-group operands read from global buffers;
//! - [`BlockStep::Barrier`] marks the `__syncthreads` the emitter
//!   placed after every shared-memory write.
//!
//! Index arithmetic is explicit: every load carries an [`IndexMap`] —
//! the composed shape-modulation chain (broadcast/reshape/transpose/
//! slice) between the loop's index space and the source buffer.

use crate::hlo::instruction::ReduceKind;
use crate::hlo::InstrId;
use crate::schedule::{SchedType, Schedule};
use std::fmt;

/// A virtual scalar register inside a [`ThreadProg`].
pub type Reg = u16;

/// Fill value the VM materializes for IR `Constant` instructions (the
/// in-memory IR carries no constant payload; 1.0 is neutral for the
/// mul/div scaling constants the benchmark graphs use it for, and both
/// the stitched VM and the op-by-op interpreter agree on it).
pub const CONST_FILL: f32 = 1.0;

// ---------------------------------------------------------------------
// Index arithmetic
// ---------------------------------------------------------------------

/// Row-major linear offset of `idx` within `dims`.
pub fn linearize(idx: &[i64], dims: &[i64]) -> i64 {
    let mut lin = 0i64;
    for (i, &d) in dims.iter().enumerate() {
        lin = lin * d.max(1) + idx.get(i).copied().unwrap_or(0);
    }
    lin
}

/// Row-major multi-index of linear offset `lin` within `dims`.
pub fn delinearize(mut lin: i64, dims: &[i64]) -> Vec<i64> {
    let mut idx = vec![0i64; dims.len()];
    for k in (0..dims.len()).rev() {
        let d = dims[k].max(1);
        idx[k] = lin % d;
        lin /= d;
    }
    idx
}

/// [`delinearize`] into a reused buffer (the VM's allocation-free path).
pub fn delinearize_into(mut lin: i64, dims: &[i64], out: &mut Vec<i64>) {
    out.clear();
    out.resize(dims.len(), 0);
    for k in (0..dims.len()).rev() {
        let d = dims[k].max(1);
        out[k] = lin % d;
        lin /= d;
    }
}

/// Linear offset of `idx` in `sched_type` order: `Row` is row-major
/// [`linearize`]; `Column` linearizes the reversed index over the
/// reversed dims — the allocation-free equivalent of the temporary
/// vectors [`chunk_offset`] builds.
pub fn sched_linearize(sched_type: SchedType, dims: &[i64], idx: &[i64]) -> i64 {
    match sched_type {
        SchedType::Row => linearize(idx, dims),
        SchedType::Column => {
            let n = dims.len();
            let mut lin = 0i64;
            for i in 0..n {
                // reversed dims/idx, walked forward
                let d = dims[n - 1 - i].max(1);
                let x = idx.get(n - 1 - i).copied().unwrap_or(0);
                lin = lin * d + x;
            }
            lin
        }
    }
}

/// One shape-modulation hop of an operand access path.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum IndexStep {
    /// `Broadcast`: operand index `i` is the current index at output
    /// dim `dims[i]` (XLA `broadcast_dimensions`).
    Gather { dims: Vec<usize> },
    /// `Reshape`/`Bitcast`: linearize row-major in `from`, delinearize
    /// in `to`.
    Relinearize { from: Vec<i64>, to: Vec<i64> },
    /// `Transpose`: operand index at dim `perm[k]` is the current index
    /// at dim `k` (output dim `k` reads input dim `perm[k]`).
    Permute { perm: Vec<usize> },
    /// `Slice`: operand index is the current index plus `starts`.
    Offset { starts: Vec<i64> },
}

/// A composed chain of [`IndexStep`]s, applied in order.
#[derive(Debug, Clone, Default, PartialEq, Eq, Hash)]
pub struct IndexMap {
    pub steps: Vec<IndexStep>,
}

impl IndexMap {
    pub fn identity() -> Self {
        IndexMap::default()
    }

    pub fn is_identity(&self) -> bool {
        self.steps.is_empty()
    }

    /// This map followed by one more step.
    pub fn then(&self, step: IndexStep) -> Self {
        let mut steps = self.steps.clone();
        steps.push(step);
        IndexMap { steps }
    }

    /// Transform a multi-index through the chain.
    pub fn apply(&self, idx: &[i64]) -> Vec<i64> {
        let mut cur: Vec<i64> = idx.to_vec();
        for step in &self.steps {
            cur = match step {
                IndexStep::Gather { dims } => dims.iter().map(|&d| cur[d]).collect(),
                IndexStep::Relinearize { from, to } => delinearize(linearize(&cur, from), to),
                IndexStep::Permute { perm } => {
                    let mut out = vec![0i64; cur.len()];
                    for (k, &p) in perm.iter().enumerate() {
                        out[p] = cur[k];
                    }
                    out
                }
                IndexStep::Offset { starts } => {
                    cur.iter().zip(starts).map(|(&i, &s)| i + s).collect()
                }
            };
        }
        cur
    }

    /// [`IndexMap::apply`] into reused buffers: the result lands in
    /// `out`, `tmp` is ping-pong scratch. Step semantics are identical
    /// to `apply` (same truncation/padding rules), with zero
    /// allocations once the buffers have grown to the chain's widest
    /// rank.
    pub fn apply_into(&self, idx: &[i64], out: &mut Vec<i64>, tmp: &mut Vec<i64>) {
        out.clear();
        out.extend_from_slice(idx);
        for step in &self.steps {
            tmp.clear();
            match step {
                IndexStep::Gather { dims } => {
                    tmp.extend(dims.iter().map(|&d| out[d]));
                }
                IndexStep::Relinearize { from, to } => {
                    delinearize_into(linearize(out, from), to, tmp);
                }
                IndexStep::Permute { perm } => {
                    tmp.resize(out.len(), 0);
                    for (k, &p) in perm.iter().enumerate() {
                        tmp[p] = out[k];
                    }
                }
                IndexStep::Offset { starts } => {
                    tmp.extend(out.iter().zip(starts).map(|(&i, &s)| i + s));
                }
            }
            std::mem::swap(out, tmp);
        }
    }
}

// ---------------------------------------------------------------------
// Affine specialization of index chains
// ---------------------------------------------------------------------

/// A linear offset as an affine function of the evaluation index:
/// `lin(idx) = base + Σ coeffs[k] * idx[k]`. Compiled at lowering time
/// from an [`IndexMap`], it turns the VM's per-element
/// `map.apply` + [`linearize`] vector churn into a handful of
/// multiply-adds.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AffineRow {
    pub base: i64,
    /// One coefficient per evaluation-index dimension.
    pub coeffs: Vec<i64>,
}

impl AffineRow {
    #[inline]
    pub fn apply(&self, idx: &[i64]) -> i64 {
        let mut lin = self.base;
        for (c, &i) in self.coeffs.iter().zip(idx) {
            lin += c * i;
        }
        lin
    }

    fn zero(rank: usize) -> Self {
        AffineRow { base: 0, coeffs: vec![0; rank] }
    }

    fn add_scaled(&mut self, other: &AffineRow, scale: i64) {
        self.base += other.base * scale;
        for (a, b) in self.coeffs.iter_mut().zip(&other.coeffs) {
            *a += b * scale;
        }
    }
}

/// Row-major strides of `dims`, matching [`linearize`]'s `d.max(1)`
/// convention: `lin = Σ idx[k] * strides[k]`.
fn row_strides(dims: &[i64]) -> Vec<i64> {
    let mut s = vec![1i64; dims.len()];
    for k in (0..dims.len().saturating_sub(1)).rev() {
        s[k] = s[k + 1] * dims[k + 1].max(1);
    }
    s
}

/// Symbolic state while walking an [`IndexMap`]: either every current
/// index dimension is affine in the evaluation index, or the chain has
/// collapsed to a single linear offset in `space` (after a
/// `Relinearize` — delinearizing symbolically is not affine, but a
/// later linearize over the same space cancels it exactly).
enum AffState {
    Multi(Vec<AffineRow>),
    Scalar { lin: AffineRow, space: Vec<i64> },
}

fn affine_state(map: &IndexMap, in_rank: usize) -> Option<AffState> {
    let mut st = AffState::Multi(
        (0..in_rank)
            .map(|k| {
                let mut r = AffineRow::zero(in_rank);
                r.coeffs[k] = 1;
                r
            })
            .collect(),
    );
    for step in &map.steps {
        st = match (st, step) {
            (AffState::Multi(rows), IndexStep::Gather { dims }) => {
                let mut next = Vec::with_capacity(dims.len());
                for &d in dims {
                    next.push(rows.get(d)?.clone());
                }
                AffState::Multi(next)
            }
            (AffState::Multi(rows), IndexStep::Permute { perm }) => {
                if perm.len() > rows.len() {
                    return None; // apply would index out of bounds
                }
                let mut next = vec![AffineRow::zero(in_rank); rows.len()];
                for (k, &p) in perm.iter().enumerate() {
                    if p >= next.len() {
                        return None;
                    }
                    next[p] = rows[k].clone();
                }
                AffState::Multi(next)
            }
            (AffState::Multi(mut rows), IndexStep::Offset { starts }) => {
                // apply zips, so the result is truncated to the shorter
                rows.truncate(rows.len().min(starts.len()));
                for (r, &s) in rows.iter_mut().zip(starts) {
                    r.base += s;
                }
                AffState::Multi(rows)
            }
            (AffState::Multi(rows), IndexStep::Relinearize { from, to }) => {
                let strides = row_strides(from);
                let mut lin = AffineRow::zero(in_rank);
                for (k, &stride) in strides.iter().enumerate() {
                    if let Some(row) = rows.get(k) {
                        lin.add_scaled(row, stride);
                    }
                }
                AffState::Scalar { lin, space: to.clone() }
            }
            (AffState::Scalar { lin, space }, IndexStep::Relinearize { from, to })
                if *from == space =>
            {
                // linearize(delinearize(lin, space), space) == lin for
                // in-range offsets, so back-to-back reshapes collapse.
                AffState::Scalar { lin, space: to.clone() }
            }
            _ => return None,
        };
    }
    Some(st)
}

/// Compile `map` (evaluated over an `in_rank`-dimensional index) into
/// the **row-major** linear offset into `dst_dims` — what every global
/// load computes per element. `None` when the chain is not affine (the
/// VM falls back to the general path).
pub fn compile_affine(map: &IndexMap, in_rank: usize, dst_dims: &[i64]) -> Option<AffineRow> {
    match affine_state(map, in_rank)? {
        AffState::Multi(rows) => {
            let strides = row_strides(dst_dims);
            let mut lin = AffineRow::zero(in_rank);
            for (k, &stride) in strides.iter().enumerate() {
                if let Some(row) = rows.get(k) {
                    lin.add_scaled(row, stride);
                }
            }
            Some(lin)
        }
        AffState::Scalar { lin, space } => {
            if space == dst_dims {
                Some(lin)
            } else {
                None
            }
        }
    }
}

/// Compile `map` into the **schedule-order** linear offset into `dims`
/// (what [`chunk_offset`] computes): `Row` is row-major, `Column`
/// linearizes the reversed index over the reversed dims.
pub fn compile_affine_sched(
    map: &IndexMap,
    in_rank: usize,
    dims: &[i64],
    sched_type: SchedType,
) -> Option<AffineRow> {
    match sched_type {
        SchedType::Row => compile_affine(map, in_rank, dims),
        SchedType::Column => match affine_state(map, in_rank)? {
            AffState::Multi(rows) => {
                let n = dims.len();
                let rev_dims: Vec<i64> = dims.iter().rev().copied().collect();
                let strides = row_strides(&rev_dims);
                let mut lin = AffineRow::zero(in_rank);
                for (i, &stride) in strides.iter().enumerate() {
                    if let Some(row) = rows.get(n - 1 - i) {
                        lin.add_scaled(row, stride);
                    }
                }
                Some(lin)
            }
            // A collapsed scalar is a row-major offset; only rank <= 1
            // spaces have identical row/column orders.
            AffState::Scalar { lin, space } => {
                if space == dims && dims.len() <= 1 {
                    Some(lin)
                } else {
                    None
                }
            }
        },
    }
}

// ---------------------------------------------------------------------
// Grid / chunk model
// ---------------------------------------------------------------------

/// Grid size `sched` launches over a work space of `dims` — mirrors
/// [`Schedule::blocks`] without constructing a [`crate::hlo::Shape`].
pub fn sched_blocks(sched: Schedule, dims: &[i64]) -> i64 {
    if dims.is_empty() {
        return 1;
    }
    let p: i64 = match sched.sched_type {
        SchedType::Row => dims[..sched.split_dim].iter().product(),
        SchedType::Column => dims[sched.split_dim + 1..].iter().product(),
    };
    (p * sched.sword).max(1)
}

/// Elements each block's chunk holds under `sched`.
pub fn sched_chunk(sched: Schedule, dims: &[i64]) -> i64 {
    let total: i64 = dims.iter().product::<i64>().max(1);
    (total / sched_blocks(sched, dims)).max(1)
}

/// Global multi-index of element `e` of `block`'s chunk: a `Row`
/// schedule partitions the row-major linear element space into
/// contiguous per-block chunks; `Column` mirrors this on the reversed
/// dims (column-major contiguity) — Fig. 5's two loop structures.
pub fn chunk_index(sched: Schedule, dims: &[i64], block: i64, e: i64) -> Vec<i64> {
    let lin = block * sched_chunk(sched, dims) + e;
    match sched.sched_type {
        SchedType::Row => delinearize(lin, dims),
        SchedType::Column => {
            let rev: Vec<i64> = dims.iter().rev().copied().collect();
            let mut idx = delinearize(lin, &rev);
            idx.reverse();
            idx
        }
    }
}

/// [`chunk_index`] into a reused buffer — no temporary reversed-dims
/// vectors (`Column` digits fall out of walking `dims` forward).
pub fn chunk_index_into(sched: Schedule, dims: &[i64], block: i64, e: i64, out: &mut Vec<i64>) {
    let mut lin = block * sched_chunk(sched, dims) + e;
    match sched.sched_type {
        SchedType::Row => delinearize_into(lin, dims, out),
        SchedType::Column => {
            out.clear();
            out.resize(dims.len(), 0);
            for i in 0..dims.len() {
                let d = dims[i].max(1);
                out[i] = lin % d;
                lin /= d;
            }
        }
    }
}

/// Chunk-local offset of global index `idx` inside `block`'s chunk, or
/// `None` when the element belongs to a different block — reading
/// `None` through shared memory is a stitching-invariant violation.
pub fn chunk_offset(sched: Schedule, dims: &[i64], block: i64, idx: &[i64]) -> Option<i64> {
    let lin = match sched.sched_type {
        SchedType::Row => linearize(idx, dims),
        SchedType::Column => {
            let rev_idx: Vec<i64> = idx.iter().rev().copied().collect();
            let rev_dims: Vec<i64> = dims.iter().rev().copied().collect();
            linearize(&rev_idx, &rev_dims)
        }
    };
    let chunk = sched_chunk(sched, dims);
    let start = block * chunk;
    if lin >= start && lin < start + chunk {
        Some(lin - start)
    } else {
        None
    }
}

// ---------------------------------------------------------------------
// Thread-level register bytecode
// ---------------------------------------------------------------------

/// Unary scalar operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnOp {
    Exp,
    Log,
    Tanh,
    Sigmoid,
    Sqrt,
    Rsqrt,
    Neg,
    Abs,
    Erf,
    Sign,
    Floor,
    Ceil,
    Not,
    Id,
}

impl UnOp {
    pub fn apply(self, x: f32) -> f32 {
        match self {
            UnOp::Exp => x.exp(),
            UnOp::Log => x.ln(),
            UnOp::Tanh => x.tanh(),
            UnOp::Sigmoid => 1.0 / (1.0 + (-x).exp()),
            UnOp::Sqrt => x.sqrt(),
            UnOp::Rsqrt => 1.0 / x.sqrt(),
            UnOp::Neg => -x,
            UnOp::Abs => x.abs(),
            UnOp::Erf => erf(x),
            UnOp::Sign => {
                if x > 0.0 {
                    1.0
                } else if x < 0.0 {
                    -1.0
                } else {
                    0.0
                }
            }
            UnOp::Floor => x.floor(),
            UnOp::Ceil => x.ceil(),
            UnOp::Not => {
                if x == 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            UnOp::Id => x,
        }
    }
}

/// Abramowitz–Stegun 7.1.26 polynomial approximation (|err| < 1.5e-7),
/// matching what a device intrinsic would deliver within f32 tolerance.
pub fn erf(x: f32) -> f32 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let poly = t
        * (0.254829592 + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429))));
    sign * (1.0 - poly * (-x * x).exp())
}

/// Binary scalar operators. `Gt` backs `Compare` (0.0 / 1.0 result).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Max,
    Min,
    Pow,
    Rem,
    Gt,
}

impl BinOp {
    pub fn apply(self, a: f32, b: f32) -> f32 {
        match self {
            BinOp::Add => a + b,
            BinOp::Sub => a - b,
            BinOp::Mul => a * b,
            BinOp::Div => a / b,
            BinOp::Max => a.max(b),
            BinOp::Min => a.min(b),
            BinOp::Pow => a.powf(b),
            BinOp::Rem => a % b,
            BinOp::Gt => {
                if a > b {
                    1.0
                } else {
                    0.0
                }
            }
        }
    }
}

/// One bytecode instruction of a [`ThreadProg`].
///
/// The load variants carry two layers: the *portable* form (`map` plus
/// shapes — what the PR-2 boxed reference path interprets) and the
/// *specialized* form filled in at lowering/planning time — compiled
/// [`AffineRow`] offsets and the operand's resolved arena range
/// ([`crate::exec::memplan::BufSlot`]). The fast path uses the
/// specialized fields and falls back to interpreting `map` when a
/// chain is not affine.
#[derive(Debug, Clone, PartialEq)]
pub enum TInstr {
    /// Load an immediate.
    Const { dst: Reg, value: f32 },
    /// Read a global (DRAM) buffer: map the current index into `src`'s
    /// index space, then row-major linearize over `dims`.
    LoadGlobal {
        dst: Reg,
        src: InstrId,
        dims: Vec<i64>,
        map: IndexMap,
        /// Compiled row-major offset (`None`: interpret `map`).
        lin: Option<AffineRow>,
        /// `src`'s arena range, baked by the memory planner.
        buf: Option<crate::exec::memplan::BufSlot>,
    },
    /// Read this block's shared-memory region at `offset`. The region
    /// holds `owner`'s per-block chunk under `owner_sched`; the mapped
    /// index must fall inside the executing block's chunk.
    LoadShared {
        dst: Reg,
        offset: usize,
        owner: InstrId,
        owner_dims: Vec<i64>,
        owner_sched: Schedule,
        map: IndexMap,
        /// Index of the region in [`KernelProgram::shm_regions`].
        slot: usize,
        /// `owner`'s per-block chunk size (elements).
        chunk: i64,
        /// Compiled schedule-order offset for the chunk check.
        sched_lin: Option<AffineRow>,
    },
    /// Read a fusion root's global output written earlier in the SAME
    /// launch. Only the executing block's own chunk of the owner is
    /// visible (a real kernel has no cross-block synchronization), so
    /// the mapped index is chunk-checked like a shared read.
    LoadOwned {
        dst: Reg,
        src: InstrId,
        dims: Vec<i64>,
        owner_sched: Schedule,
        map: IndexMap,
        /// `owner_sched`'s per-block chunk size (elements).
        chunk: i64,
        /// Compiled row-major offset into `src`'s buffer.
        lin: Option<AffineRow>,
        /// Compiled schedule-order offset for the chunk check.
        sched_lin: Option<AffineRow>,
        /// `src`'s arena range, baked by the memory planner.
        buf: Option<crate::exec::memplan::BufSlot>,
    },
    Unary { dst: Reg, a: Reg, op: UnOp },
    Binary { dst: Reg, a: Reg, b: Reg, op: BinOp },
    Select { dst: Reg, pred: Reg, on_true: Reg, on_false: Reg },
    /// `Concatenate` dispatch: map into the concat's output space, pick
    /// the case whose slab of `dim` contains the index (cumulative
    /// `limits`), rebase the index into the operand and evaluate that
    /// case's sub-program.
    Branch { dst: Reg, map: IndexMap, dim: usize, limits: Vec<i64>, cases: Vec<ThreadProg> },
}

/// Straight-line register program computing one scalar, evaluated at a
/// multi-index of its index space.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ThreadProg {
    pub n_regs: Reg,
    pub code: Vec<TInstr>,
    pub out: Reg,
}

// ---------------------------------------------------------------------
// Block-level program
// ---------------------------------------------------------------------

/// How a stitched loop combines its inputs per output element.
#[derive(Debug, Clone, PartialEq)]
pub enum LoopKind {
    /// Elementwise / shape-modulation loop: one [`ThreadProg`] per
    /// output element (thread-composed producers inlined).
    Map { prog: ThreadProg },
    /// Reduction loop: per output element, fold the operand program
    /// over the reduced dims of `in_dims` (row-major, dims ascending).
    /// `kept` (the non-reduced dims, ascending) and `sizes` (the
    /// reduced extents, aligned with `dims`) are precomputed at
    /// lowering so the fast path rebuilds input indices without
    /// per-element allocation.
    Reduce {
        kind: ReduceKind,
        dims: Vec<usize>,
        in_dims: Vec<i64>,
        operand: ThreadProg,
        kept: Vec<usize>,
        sizes: Vec<i64>,
    },
    /// Batched-matmul loop: per output element `[..., m, n]`,
    /// accumulate `lhs[..., m, k] * rhs[..., k, n]` over `k` ascending.
    Dot { lhs: ThreadProg, rhs: ThreadProg, lhs_dims: Vec<i64>, rhs_dims: Vec<i64> },
}

/// Where a stitched loop deposits its chunk.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WriteTarget {
    /// `EmitWriteSharedArray` — the block's shared region at byte
    /// `offset` (`slot` indexes [`KernelProgram::shm_regions`]).
    Shared { offset: usize, slot: usize },
    /// `EmitWriteOutputArray` — the op's global output buffer.
    Output,
    /// `EmitWriteSpillArray` — the op's grid-visible global spill
    /// region (third stitching tier). Written exactly like `Output`;
    /// a [`BlockStep::GridFence`] follows before any consumer reads.
    Spill,
}

/// One shared-memory region of a kernel's per-block scratch, in the
/// flat f32 layout the fast path uses (`base..base + elems` inside the
/// block's shared buffer). Distinct byte offsets of the shm planner
/// become distinct regions; space-sharing owners rotate through the
/// same region.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShmRegion {
    pub base: usize,
    pub elems: usize,
}

/// One per-block step of a kernel.
#[derive(Debug, Clone, PartialEq)]
pub enum BlockStep {
    /// A stitched parallel loop over `op`'s per-block chunk of `dims`
    /// under `sched`.
    Loop { op: InstrId, dims: Vec<i64>, sched: Schedule, kind: LoopKind, write: WriteTarget },
    /// `__syncthreads` after a shared write (block composition fence).
    Barrier,
    /// Grid-wide fence after a spill write (`grid.sync`): every block
    /// must finish all steps before this one before any block runs a
    /// later step. The VM splits the step list into phases here and
    /// joins all block threads between phases.
    GridFence,
}

/// One fused group, lowered: a single launch.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelProgram {
    pub name: String,
    /// Fusion-plan group this kernel implements.
    pub group_id: usize,
    /// Launch dimensions (the tuned grid).
    pub blocks: u64,
    pub threads: u32,
    /// Peak shared memory modeled per block.
    pub shm_bytes: usize,
    /// Flat layout of the block's shared regions (indexed by the
    /// `slot` fields of shared writes/reads).
    pub shm_regions: Vec<ShmRegion>,
    pub steps: Vec<BlockStep>,
    /// Global output buffers this kernel writes: `(root, elems)`.
    pub outputs: Vec<(InstrId, usize)>,
    /// Grid-visible spill regions this kernel writes (third stitching
    /// tier): `(op, elems)`. Packed into the value arena with the same
    /// liveness discipline as outputs; live only within this launch.
    pub spills: Vec<(InstrId, usize)>,
    /// Structural fingerprint of the fused group this kernel implements
    /// ([`crate::fusion::group_fingerprint`]) — the identity the
    /// explore pass memoizes modeled costs under, carried here so the
    /// obs layer's measured launch times join 1:1 with the cost model.
    pub group_fp: u64,
    /// The explore/tuning pass's modeled execution time for this
    /// kernel, µs (0 when the group was never priced).
    pub modeled_us: f64,
}

/// Which stitching tier a kernel executes under — attributed per
/// launch in [`super::LaunchLedger`] so benches and serving stats can
/// tell which tier earned a launch reduction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StitchTier {
    /// No cross-emitter intermediates (plain / thread-composed kernel).
    Plain,
    /// Block composition through shared memory (§5.1).
    Shm,
    /// Global-memory stitching with grid-wide fences (third tier).
    Global,
}

impl KernelProgram {
    /// The stitching tier this kernel executes under — a static
    /// property of the program, so both VM paths agree trivially.
    pub fn stitch_tier(&self) -> StitchTier {
        if !self.spills.is_empty() {
            StitchTier::Global
        } else if !self.shm_regions.is_empty() {
            StitchTier::Shm
        } else {
            StitchTier::Plain
        }
    }

    /// Human-readable disassembly (the executable counterpart of
    /// [`crate::codegen::KernelPlan::ir_text`]).
    pub fn disasm(&self) -> String {
        let mut out = format!(
            "kernel {} <<<{}, {}>>> smem={}B group={}\n",
            self.name, self.blocks, self.threads, self.shm_bytes, self.group_id
        );
        for step in &self.steps {
            match step {
                BlockStep::Barrier => out.push_str("  barrier\n"),
                BlockStep::GridFence => out.push_str("  grid_fence\n"),
                BlockStep::Loop { op, sched, kind, write, .. } => {
                    let kind_s = match kind {
                        LoopKind::Map { prog } => format!("map[{} instrs]", prog.code.len()),
                        LoopKind::Reduce { kind, dims, .. } => {
                            format!("reduce.{kind:?} dims={dims:?}")
                        }
                        LoopKind::Dot { .. } => "batch_dot".to_string(),
                    };
                    let write_s = match write {
                        WriteTarget::Shared { offset, .. } => format!("shared@{offset}"),
                        WriteTarget::Output => "output".to_string(),
                        WriteTarget::Spill => "spill".to_string(),
                    };
                    out.push_str(&format!(
                        "  loop %{} {} sched={} -> {}\n",
                        op.0, kind_s, sched, write_s
                    ));
                }
            }
        }
        out
    }
}

impl fmt::Display for KernelProgram {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.disasm())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::Shape;

    #[test]
    fn linearize_delinearize_roundtrip() {
        let dims = [2i64, 3, 4];
        for lin in 0..24 {
            let idx = delinearize(lin, &dims);
            assert_eq!(linearize(&idx, &dims), lin);
        }
        assert_eq!(delinearize(0, &[]), Vec::<i64>::new());
        assert_eq!(linearize(&[], &[]), 0);
    }

    #[test]
    fn chunk_partition_covers_every_element_once() {
        let dims = vec![4i64, 6, 8];
        let shape = Shape::f32(&dims);
        for sched in Schedule::enumerate(&shape) {
            let blocks = sched_blocks(sched, &dims);
            assert_eq!(blocks as u64, sched.blocks(&shape), "{sched}");
            let chunk = sched_chunk(sched, &dims);
            let mut seen = vec![false; 192];
            for b in 0..blocks {
                for e in 0..chunk {
                    let idx = chunk_index(sched, &dims, b, e);
                    let lin = linearize(&idx, &dims) as usize;
                    assert!(!seen[lin], "{sched}: element {lin} visited twice");
                    seen[lin] = true;
                    // chunk_offset inverts chunk_index
                    assert_eq!(chunk_offset(sched, &dims, b, &idx), Some(e), "{sched}");
                    // and the element belongs to no other block
                    let other = (b + 1) % blocks;
                    if blocks > 1 {
                        assert_eq!(chunk_offset(sched, &dims, other, &idx), None, "{sched}");
                    }
                }
            }
            assert!(seen.iter().all(|&s| s), "{sched}: partition incomplete");
        }
    }

    #[test]
    fn index_map_composition() {
        // broadcast [64] -> [8, 64] on dim 1, then transpose-like identity
        let m = IndexMap::identity().then(IndexStep::Gather { dims: vec![1] });
        assert_eq!(m.apply(&[3, 17]), vec![17]);
        // reshape [8, 64] -> [512]
        let m2 = IndexMap::identity()
            .then(IndexStep::Relinearize { from: vec![8, 64], to: vec![512] });
        assert_eq!(m2.apply(&[2, 5]), vec![133]);
        // transpose perm [0, 2, 1]: out[k] reads in[perm[k]]
        let m3 = IndexMap::identity().then(IndexStep::Permute { perm: vec![0, 2, 1] });
        assert_eq!(m3.apply(&[1, 2, 3]), vec![1, 3, 2]);
        // slice offset
        let m4 = IndexMap::identity().then(IndexStep::Offset { starts: vec![1, 2] });
        assert_eq!(m4.apply(&[0, 0]), vec![1, 2]);
    }

    /// Deterministic pseudo-random step chains for exercising the
    /// affine compiler against the reference interpreter.
    fn test_maps() -> Vec<(IndexMap, usize, Vec<i64>)> {
        let mut cases = Vec::new();
        // identity into various spaces
        cases.push((IndexMap::identity(), 3, vec![4, 5, 6]));
        cases.push((IndexMap::identity(), 0, vec![]));
        // broadcast [5] -> [4, 5]
        cases.push((
            IndexMap::identity().then(IndexStep::Gather { dims: vec![1] }),
            2,
            vec![5],
        ));
        // broadcast scalar -> [4, 5]
        cases.push((IndexMap::identity().then(IndexStep::Gather { dims: vec![] }), 2, vec![]));
        // transpose [4, 5, 6] reading [4, 6, 5]
        cases.push((
            IndexMap::identity().then(IndexStep::Permute { perm: vec![0, 2, 1] }),
            3,
            vec![4, 6, 5],
        ));
        // slice into [8, 9] with starts [1, 2]
        cases.push((
            IndexMap::identity().then(IndexStep::Offset { starts: vec![1, 2] }),
            2,
            vec![8, 9],
        ));
        // reshape [4, 6] -> [24] then read flat
        cases.push((
            IndexMap::identity()
                .then(IndexStep::Relinearize { from: vec![4, 6], to: vec![24] }),
            2,
            vec![24],
        ));
        // reshape [4, 6] -> [2, 12] -> [24]: back-to-back collapse
        cases.push((
            IndexMap::identity()
                .then(IndexStep::Relinearize { from: vec![4, 6], to: vec![2, 12] })
                .then(IndexStep::Relinearize { from: vec![2, 12], to: vec![24] }),
            2,
            vec![24],
        ));
        // broadcast + transpose + offset composed
        cases.push((
            IndexMap::identity()
                .then(IndexStep::Gather { dims: vec![1, 0] })
                .then(IndexStep::Offset { starts: vec![2, 3] }),
            2,
            vec![9, 8],
        ));
        // gather then reshape to flat
        cases.push((
            IndexMap::identity()
                .then(IndexStep::Gather { dims: vec![0] })
                .then(IndexStep::Relinearize { from: vec![4], to: vec![4] }),
            2,
            vec![4],
        ));
        cases
    }

    /// Index grids of the evaluation space (small exhaustive sweep).
    fn eval_indices(rank: usize) -> Vec<Vec<i64>> {
        match rank {
            0 => vec![vec![]],
            1 => (0..4).map(|i| vec![i]).collect(),
            2 => (0..4).flat_map(|i| (0..5).map(move |j| vec![i, j])).collect(),
            _ => (0..3)
                .flat_map(|i| {
                    (0..4).flat_map(move |j| (0..5).map(move |k| vec![i, j, k]))
                })
                .collect(),
        }
    }

    #[test]
    fn affine_compile_matches_reference() {
        for (map, rank, dims) in test_maps() {
            let affine = compile_affine(&map, rank, &dims)
                .unwrap_or_else(|| panic!("{map:?} over rank {rank} should be affine"));
            for idx in eval_indices(rank) {
                let j = map.apply(&idx);
                let want = linearize(&j, &dims);
                assert_eq!(
                    affine.apply(&idx),
                    want,
                    "{map:?} at {idx:?} (mapped {j:?}, dims {dims:?})"
                );
            }
        }
    }

    #[test]
    fn affine_sched_matches_chunk_offset_linearization() {
        for (map, rank, dims) in test_maps() {
            for ty in [SchedType::Row, SchedType::Column] {
                let Some(affine) = compile_affine_sched(&map, rank, &dims, ty) else {
                    continue; // column scalar collapse legitimately bails
                };
                for idx in eval_indices(rank) {
                    let j = map.apply(&idx);
                    assert_eq!(
                        affine.apply(&idx),
                        sched_linearize(ty, &dims, &j),
                        "{map:?} {ty:?} at {idx:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn non_affine_chains_fall_back() {
        // reshape followed by a permute in the reshaped space: not
        // affine (the delinearize cannot be cancelled).
        let m = IndexMap::identity()
            .then(IndexStep::Relinearize { from: vec![4, 6], to: vec![2, 12] })
            .then(IndexStep::Permute { perm: vec![1, 0] });
        assert!(compile_affine(&m, 2, &[12, 2]).is_none());
        // ... but the general apply_into path still evaluates it.
        let mut out = Vec::new();
        let mut tmp = Vec::new();
        for idx in eval_indices(2) {
            m.apply_into(&idx, &mut out, &mut tmp);
            assert_eq!(out, m.apply(&idx), "{m:?} at {idx:?}");
        }
    }

    #[test]
    fn apply_into_matches_apply_everywhere() {
        let mut out = Vec::new();
        let mut tmp = Vec::new();
        for (map, rank, _) in test_maps() {
            for idx in eval_indices(rank) {
                map.apply_into(&idx, &mut out, &mut tmp);
                assert_eq!(out, map.apply(&idx), "{map:?} at {idx:?}");
            }
        }
    }

    #[test]
    fn chunk_index_into_and_sched_linearize_match_reference() {
        let dims = vec![4i64, 6, 8];
        let shape = Shape::f32(&dims);
        let mut buf = Vec::new();
        for sched in Schedule::enumerate(&shape) {
            let blocks = sched_blocks(sched, &dims);
            let chunk = sched_chunk(sched, &dims);
            for b in 0..blocks {
                for e in 0..chunk {
                    let want = chunk_index(sched, &dims, b, e);
                    chunk_index_into(sched, &dims, b, e, &mut buf);
                    assert_eq!(buf, want, "{sched} block {b} elem {e}");
                    // sched_linearize inverts the chunk walk
                    assert_eq!(
                        sched_linearize(sched.sched_type, &dims, &want),
                        b * chunk + e,
                        "{sched}"
                    );
                }
            }
        }
    }

    #[test]
    fn scalar_ops_match_std() {
        assert_eq!(BinOp::Gt.apply(2.0, 1.0), 1.0);
        assert_eq!(BinOp::Gt.apply(1.0, 2.0), 0.0);
        assert_eq!(UnOp::Not.apply(0.0), 1.0);
        assert_eq!(UnOp::Sign.apply(-3.0), -1.0);
        assert!((erf(1.0) - 0.8427007).abs() < 1e-5);
        assert!((erf(-1.0) + 0.8427007).abs() < 1e-5);
    }
}
