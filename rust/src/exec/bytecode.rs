//! The stitched-kernel bytecode: what a [`crate::codegen::KernelPlan`]
//! lowers to and what the VM ([`super::machine`]) executes.
//!
//! One fused group becomes one [`KernelProgram`] — a single launch. The
//! program models the GPU grid explicitly:
//!
//! - the **block loop** runs every [`BlockStep`] once per thread block
//!   (grid size = the tuned `blocks`);
//! - each [`BlockStep::Loop`] is one stitched parallel loop (Algorithm
//!   2's `StitchedEmitter`): it walks the op's per-block chunk of its
//!   work space under the op's tuned [`Schedule`] with a **thread
//!   loop** striding by `threads`;
//! - per output element a [`ThreadProg`] runs — straight-line register
//!   bytecode with the elemental (thread-composed) producers inlined,
//!   shared-memory operands read from the block's shared regions and
//!   out-of-group operands read from global buffers;
//! - [`BlockStep::Barrier`] marks the `__syncthreads` the emitter
//!   placed after every shared-memory write.
//!
//! Index arithmetic is explicit: every load carries an [`IndexMap`] —
//! the composed shape-modulation chain (broadcast/reshape/transpose/
//! slice) between the loop's index space and the source buffer.

use crate::hlo::instruction::ReduceKind;
use crate::hlo::InstrId;
use crate::schedule::{SchedType, Schedule};
use std::fmt;

/// A virtual scalar register inside a [`ThreadProg`].
pub type Reg = u16;

/// Fill value the VM materializes for IR `Constant` instructions (the
/// in-memory IR carries no constant payload; 1.0 is neutral for the
/// mul/div scaling constants the benchmark graphs use it for, and both
/// the stitched VM and the op-by-op interpreter agree on it).
pub const CONST_FILL: f32 = 1.0;

// ---------------------------------------------------------------------
// Index arithmetic
// ---------------------------------------------------------------------

/// Row-major linear offset of `idx` within `dims`.
pub fn linearize(idx: &[i64], dims: &[i64]) -> i64 {
    let mut lin = 0i64;
    for (i, &d) in dims.iter().enumerate() {
        lin = lin * d.max(1) + idx.get(i).copied().unwrap_or(0);
    }
    lin
}

/// Row-major multi-index of linear offset `lin` within `dims`.
pub fn delinearize(mut lin: i64, dims: &[i64]) -> Vec<i64> {
    let mut idx = vec![0i64; dims.len()];
    for k in (0..dims.len()).rev() {
        let d = dims[k].max(1);
        idx[k] = lin % d;
        lin /= d;
    }
    idx
}

/// One shape-modulation hop of an operand access path.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum IndexStep {
    /// `Broadcast`: operand index `i` is the current index at output
    /// dim `dims[i]` (XLA `broadcast_dimensions`).
    Gather { dims: Vec<usize> },
    /// `Reshape`/`Bitcast`: linearize row-major in `from`, delinearize
    /// in `to`.
    Relinearize { from: Vec<i64>, to: Vec<i64> },
    /// `Transpose`: operand index at dim `perm[k]` is the current index
    /// at dim `k` (output dim `k` reads input dim `perm[k]`).
    Permute { perm: Vec<usize> },
    /// `Slice`: operand index is the current index plus `starts`.
    Offset { starts: Vec<i64> },
}

/// A composed chain of [`IndexStep`]s, applied in order.
#[derive(Debug, Clone, Default, PartialEq, Eq, Hash)]
pub struct IndexMap {
    pub steps: Vec<IndexStep>,
}

impl IndexMap {
    pub fn identity() -> Self {
        IndexMap::default()
    }

    pub fn is_identity(&self) -> bool {
        self.steps.is_empty()
    }

    /// This map followed by one more step.
    pub fn then(&self, step: IndexStep) -> Self {
        let mut steps = self.steps.clone();
        steps.push(step);
        IndexMap { steps }
    }

    /// Transform a multi-index through the chain.
    pub fn apply(&self, idx: &[i64]) -> Vec<i64> {
        let mut cur: Vec<i64> = idx.to_vec();
        for step in &self.steps {
            cur = match step {
                IndexStep::Gather { dims } => dims.iter().map(|&d| cur[d]).collect(),
                IndexStep::Relinearize { from, to } => delinearize(linearize(&cur, from), to),
                IndexStep::Permute { perm } => {
                    let mut out = vec![0i64; cur.len()];
                    for (k, &p) in perm.iter().enumerate() {
                        out[p] = cur[k];
                    }
                    out
                }
                IndexStep::Offset { starts } => {
                    cur.iter().zip(starts).map(|(&i, &s)| i + s).collect()
                }
            };
        }
        cur
    }
}

// ---------------------------------------------------------------------
// Grid / chunk model
// ---------------------------------------------------------------------

/// Grid size `sched` launches over a work space of `dims` — mirrors
/// [`Schedule::blocks`] without constructing a [`crate::hlo::Shape`].
pub fn sched_blocks(sched: Schedule, dims: &[i64]) -> i64 {
    if dims.is_empty() {
        return 1;
    }
    let p: i64 = match sched.sched_type {
        SchedType::Row => dims[..sched.split_dim].iter().product(),
        SchedType::Column => dims[sched.split_dim + 1..].iter().product(),
    };
    (p * sched.sword).max(1)
}

/// Elements each block's chunk holds under `sched`.
pub fn sched_chunk(sched: Schedule, dims: &[i64]) -> i64 {
    let total: i64 = dims.iter().product::<i64>().max(1);
    (total / sched_blocks(sched, dims)).max(1)
}

/// Global multi-index of element `e` of `block`'s chunk: a `Row`
/// schedule partitions the row-major linear element space into
/// contiguous per-block chunks; `Column` mirrors this on the reversed
/// dims (column-major contiguity) — Fig. 5's two loop structures.
pub fn chunk_index(sched: Schedule, dims: &[i64], block: i64, e: i64) -> Vec<i64> {
    let lin = block * sched_chunk(sched, dims) + e;
    match sched.sched_type {
        SchedType::Row => delinearize(lin, dims),
        SchedType::Column => {
            let rev: Vec<i64> = dims.iter().rev().copied().collect();
            let mut idx = delinearize(lin, &rev);
            idx.reverse();
            idx
        }
    }
}

/// Chunk-local offset of global index `idx` inside `block`'s chunk, or
/// `None` when the element belongs to a different block — reading
/// `None` through shared memory is a stitching-invariant violation.
pub fn chunk_offset(sched: Schedule, dims: &[i64], block: i64, idx: &[i64]) -> Option<i64> {
    let lin = match sched.sched_type {
        SchedType::Row => linearize(idx, dims),
        SchedType::Column => {
            let rev_idx: Vec<i64> = idx.iter().rev().copied().collect();
            let rev_dims: Vec<i64> = dims.iter().rev().copied().collect();
            linearize(&rev_idx, &rev_dims)
        }
    };
    let chunk = sched_chunk(sched, dims);
    let start = block * chunk;
    if lin >= start && lin < start + chunk {
        Some(lin - start)
    } else {
        None
    }
}

// ---------------------------------------------------------------------
// Thread-level register bytecode
// ---------------------------------------------------------------------

/// Unary scalar operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnOp {
    Exp,
    Log,
    Tanh,
    Sigmoid,
    Sqrt,
    Rsqrt,
    Neg,
    Abs,
    Erf,
    Sign,
    Floor,
    Ceil,
    Not,
    Id,
}

impl UnOp {
    pub fn apply(self, x: f32) -> f32 {
        match self {
            UnOp::Exp => x.exp(),
            UnOp::Log => x.ln(),
            UnOp::Tanh => x.tanh(),
            UnOp::Sigmoid => 1.0 / (1.0 + (-x).exp()),
            UnOp::Sqrt => x.sqrt(),
            UnOp::Rsqrt => 1.0 / x.sqrt(),
            UnOp::Neg => -x,
            UnOp::Abs => x.abs(),
            UnOp::Erf => erf(x),
            UnOp::Sign => {
                if x > 0.0 {
                    1.0
                } else if x < 0.0 {
                    -1.0
                } else {
                    0.0
                }
            }
            UnOp::Floor => x.floor(),
            UnOp::Ceil => x.ceil(),
            UnOp::Not => {
                if x == 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            UnOp::Id => x,
        }
    }
}

/// Abramowitz–Stegun 7.1.26 polynomial approximation (|err| < 1.5e-7),
/// matching what a device intrinsic would deliver within f32 tolerance.
pub fn erf(x: f32) -> f32 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let poly = t
        * (0.254829592 + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429))));
    sign * (1.0 - poly * (-x * x).exp())
}

/// Binary scalar operators. `Gt` backs `Compare` (0.0 / 1.0 result).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Max,
    Min,
    Pow,
    Rem,
    Gt,
}

impl BinOp {
    pub fn apply(self, a: f32, b: f32) -> f32 {
        match self {
            BinOp::Add => a + b,
            BinOp::Sub => a - b,
            BinOp::Mul => a * b,
            BinOp::Div => a / b,
            BinOp::Max => a.max(b),
            BinOp::Min => a.min(b),
            BinOp::Pow => a.powf(b),
            BinOp::Rem => a % b,
            BinOp::Gt => {
                if a > b {
                    1.0
                } else {
                    0.0
                }
            }
        }
    }
}

/// One bytecode instruction of a [`ThreadProg`].
#[derive(Debug, Clone, PartialEq)]
pub enum TInstr {
    /// Load an immediate.
    Const { dst: Reg, value: f32 },
    /// Read a global (DRAM) buffer: map the current index into `src`'s
    /// index space, then row-major linearize over `dims`.
    LoadGlobal { dst: Reg, src: InstrId, dims: Vec<i64>, map: IndexMap },
    /// Read this block's shared-memory region at `offset`. The region
    /// holds `owner`'s per-block chunk under `owner_sched`; the mapped
    /// index must fall inside the executing block's chunk.
    LoadShared {
        dst: Reg,
        offset: usize,
        owner: InstrId,
        owner_dims: Vec<i64>,
        owner_sched: Schedule,
        map: IndexMap,
    },
    /// Read a fusion root's global output written earlier in the SAME
    /// launch. Only the executing block's own chunk of the owner is
    /// visible (a real kernel has no cross-block synchronization), so
    /// the mapped index is chunk-checked like a shared read.
    LoadOwned { dst: Reg, src: InstrId, dims: Vec<i64>, owner_sched: Schedule, map: IndexMap },
    Unary { dst: Reg, a: Reg, op: UnOp },
    Binary { dst: Reg, a: Reg, b: Reg, op: BinOp },
    Select { dst: Reg, pred: Reg, on_true: Reg, on_false: Reg },
    /// `Concatenate` dispatch: map into the concat's output space, pick
    /// the case whose slab of `dim` contains the index (cumulative
    /// `limits`), rebase the index into the operand and evaluate that
    /// case's sub-program.
    Branch { dst: Reg, map: IndexMap, dim: usize, limits: Vec<i64>, cases: Vec<ThreadProg> },
}

/// Straight-line register program computing one scalar, evaluated at a
/// multi-index of its index space.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ThreadProg {
    pub n_regs: Reg,
    pub code: Vec<TInstr>,
    pub out: Reg,
}

// ---------------------------------------------------------------------
// Block-level program
// ---------------------------------------------------------------------

/// How a stitched loop combines its inputs per output element.
#[derive(Debug, Clone, PartialEq)]
pub enum LoopKind {
    /// Elementwise / shape-modulation loop: one [`ThreadProg`] per
    /// output element (thread-composed producers inlined).
    Map { prog: ThreadProg },
    /// Reduction loop: per output element, fold the operand program
    /// over the reduced dims of `in_dims` (row-major, dims ascending).
    Reduce { kind: ReduceKind, dims: Vec<usize>, in_dims: Vec<i64>, operand: ThreadProg },
    /// Batched-matmul loop: per output element `[..., m, n]`,
    /// accumulate `lhs[..., m, k] * rhs[..., k, n]` over `k` ascending.
    Dot { lhs: ThreadProg, rhs: ThreadProg, lhs_dims: Vec<i64>, rhs_dims: Vec<i64> },
}

/// Where a stitched loop deposits its chunk.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WriteTarget {
    /// `EmitWriteSharedArray` — the block's shared region at `offset`.
    Shared { offset: usize },
    /// `EmitWriteOutputArray` — the op's global output buffer.
    Output,
}

/// One per-block step of a kernel.
#[derive(Debug, Clone, PartialEq)]
pub enum BlockStep {
    /// A stitched parallel loop over `op`'s per-block chunk of `dims`
    /// under `sched`.
    Loop { op: InstrId, dims: Vec<i64>, sched: Schedule, kind: LoopKind, write: WriteTarget },
    /// `__syncthreads` after a shared write (block composition fence).
    Barrier,
}

/// One fused group, lowered: a single launch.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelProgram {
    pub name: String,
    /// Fusion-plan group this kernel implements.
    pub group_id: usize,
    /// Launch dimensions (the tuned grid).
    pub blocks: u64,
    pub threads: u32,
    /// Peak shared memory modeled per block.
    pub shm_bytes: usize,
    pub steps: Vec<BlockStep>,
    /// Global output buffers this kernel writes: `(root, elems)`.
    pub outputs: Vec<(InstrId, usize)>,
}

impl KernelProgram {
    /// Human-readable disassembly (the executable counterpart of
    /// [`crate::codegen::KernelPlan::ir_text`]).
    pub fn disasm(&self) -> String {
        let mut out = format!(
            "kernel {} <<<{}, {}>>> smem={}B group={}\n",
            self.name, self.blocks, self.threads, self.shm_bytes, self.group_id
        );
        for step in &self.steps {
            match step {
                BlockStep::Barrier => out.push_str("  barrier\n"),
                BlockStep::Loop { op, sched, kind, write, .. } => {
                    let kind_s = match kind {
                        LoopKind::Map { prog } => format!("map[{} instrs]", prog.code.len()),
                        LoopKind::Reduce { kind, dims, .. } => {
                            format!("reduce.{kind:?} dims={dims:?}")
                        }
                        LoopKind::Dot { .. } => "batch_dot".to_string(),
                    };
                    let write_s = match write {
                        WriteTarget::Shared { offset } => format!("shared@{offset}"),
                        WriteTarget::Output => "output".to_string(),
                    };
                    out.push_str(&format!(
                        "  loop %{} {} sched={} -> {}\n",
                        op.0, kind_s, sched, write_s
                    ));
                }
            }
        }
        out
    }
}

impl fmt::Display for KernelProgram {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.disasm())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::Shape;

    #[test]
    fn linearize_delinearize_roundtrip() {
        let dims = [2i64, 3, 4];
        for lin in 0..24 {
            let idx = delinearize(lin, &dims);
            assert_eq!(linearize(&idx, &dims), lin);
        }
        assert_eq!(delinearize(0, &[]), Vec::<i64>::new());
        assert_eq!(linearize(&[], &[]), 0);
    }

    #[test]
    fn chunk_partition_covers_every_element_once() {
        let dims = vec![4i64, 6, 8];
        let shape = Shape::f32(&dims);
        for sched in Schedule::enumerate(&shape) {
            let blocks = sched_blocks(sched, &dims);
            assert_eq!(blocks as u64, sched.blocks(&shape), "{sched}");
            let chunk = sched_chunk(sched, &dims);
            let mut seen = vec![false; 192];
            for b in 0..blocks {
                for e in 0..chunk {
                    let idx = chunk_index(sched, &dims, b, e);
                    let lin = linearize(&idx, &dims) as usize;
                    assert!(!seen[lin], "{sched}: element {lin} visited twice");
                    seen[lin] = true;
                    // chunk_offset inverts chunk_index
                    assert_eq!(chunk_offset(sched, &dims, b, &idx), Some(e), "{sched}");
                    // and the element belongs to no other block
                    let other = (b + 1) % blocks;
                    if blocks > 1 {
                        assert_eq!(chunk_offset(sched, &dims, other, &idx), None, "{sched}");
                    }
                }
            }
            assert!(seen.iter().all(|&s| s), "{sched}: partition incomplete");
        }
    }

    #[test]
    fn index_map_composition() {
        // broadcast [64] -> [8, 64] on dim 1, then transpose-like identity
        let m = IndexMap::identity().then(IndexStep::Gather { dims: vec![1] });
        assert_eq!(m.apply(&[3, 17]), vec![17]);
        // reshape [8, 64] -> [512]
        let m2 = IndexMap::identity()
            .then(IndexStep::Relinearize { from: vec![8, 64], to: vec![512] });
        assert_eq!(m2.apply(&[2, 5]), vec![133]);
        // transpose perm [0, 2, 1]: out[k] reads in[perm[k]]
        let m3 = IndexMap::identity().then(IndexStep::Permute { perm: vec![0, 2, 1] });
        assert_eq!(m3.apply(&[1, 2, 3]), vec![1, 3, 2]);
        // slice offset
        let m4 = IndexMap::identity().then(IndexStep::Offset { starts: vec![1, 2] });
        assert_eq!(m4.apply(&[0, 0]), vec![1, 2]);
    }

    #[test]
    fn scalar_ops_match_std() {
        assert_eq!(BinOp::Gt.apply(2.0, 1.0), 1.0);
        assert_eq!(BinOp::Gt.apply(1.0, 2.0), 0.0);
        assert_eq!(UnOp::Not.apply(0.0), 1.0);
        assert_eq!(UnOp::Sign.apply(-3.0), -1.0);
        assert!((erf(1.0) - 0.8427007).abs() < 1e-5);
        assert!((erf(-1.0) + 0.8427007).abs() < 1e-5);
    }
}
