//! Static buffer assignment for the stitched VM — the memory-planning
//! pass that makes the hot execute path allocation-free.
//!
//! The PR-2 VM materialized every value as its own `Vec<f32>` inside a
//! `Vec<Option<Vec<f32>>>`, re-allocated on every run; the follow-up
//! FusionStitching work (arXiv 1911.11576) and the XLA fusion study
//! (arXiv 2301.13062) both attribute much of fusion's win to buffer
//! reuse, and a serving worker that mallocs per instruction burns its
//! core on the allocator instead of the kernel. This pass runs once at
//! lowering time:
//!
//! 1. **Liveness** ([`liveness`]): the launch sequence of a
//!    [`StitchedExecutable`] is a straight line, so each materialized
//!    value (parameter, constant, kernel root, library output) has an
//!    interval `[def, last_use]` over launch points — point `0` is
//!    entry (parameters/constants), point `i + 1` is launch `i`, and
//!    the module root is pinned live to the end.
//! 2. **Assignment** ([`MemoryPlan::compute`]): a deterministic
//!    first-fit free-list walks the defs in launch order and packs
//!    every value into one flat `f32` arena; two values share bytes
//!    only when their lifetimes are disjoint (asserted by unit tests
//!    and the corpus-wide differential suite).
//! 3. **Resolution** ([`resolve`]): every per-element load in the
//!    bytecode gets its operand's `(offset, len)` baked in
//!    ([`BufSlot`]), so the VM's inner loop does strided address math
//!    instead of chasing `Option<Vec<f32>>`s.
//!
//! At run time a pooled [`super::machine::ExecArena`] holds the arena;
//! after the first run on a serving worker the plan's high-water mark
//! is resident and steady-state execution performs **zero arena
//! allocations** (counted by the arena's reuse counter and surfaced in
//! serving stats).

use super::bytecode::{BlockStep, LoopKind, TInstr, ThreadProg};
use super::machine::{Launch, LibKind, LibraryCall, StitchedExecutable};

/// A resolved arena range: where a materialized value lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BufSlot {
    /// Element offset of the value inside the arena.
    pub off: usize,
    /// Element length of the value's buffer.
    pub elems: usize,
}

/// One value's lifetime over launch points (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ValueLife {
    /// Launch point that materializes the value (0 = entry).
    pub def: usize,
    /// Last launch point that reads it (root: one past the last launch).
    pub last_use: usize,
    /// Buffer size in elements (at least 1).
    pub elems: usize,
}

impl ValueLife {
    /// Do two lifetimes overlap in time? Overlapping values must not
    /// share arena ranges.
    pub fn overlaps(&self, other: &ValueLife) -> bool {
        self.def <= other.last_use && other.def <= self.last_use
    }
}

/// What the planner decided for one executable: an arena range per
/// materialized value plus the arena's total extent.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MemoryPlan {
    /// Indexed by `InstrId.0`; `None` for values that are never
    /// materialized (thread-composed ops live in registers).
    pub slots: Vec<Option<BufSlot>>,
    /// High-water mark of the arena, in elements.
    pub arena_elems: usize,
    /// Sum of every materialized value's size, in elements — what the
    /// boxed VM allocated per run.
    pub total_value_elems: usize,
}

/// Plan-level compression numbers, surfaced on `CompiledModule` and in
/// serving stats so the buffer-reuse win is observable per model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ArenaStats {
    /// Bytes the plan actually reserves (arena high-water mark).
    pub arena_bytes: usize,
    /// Bytes the values would need without lifetime reuse.
    pub value_bytes: usize,
}

impl ArenaStats {
    /// How much bigger the un-reused footprint is than the arena
    /// (`>= 1.0`; `1.0` means no range was ever reused).
    pub fn reuse_ratio(&self) -> f64 {
        if self.arena_bytes == 0 {
            1.0
        } else {
            self.value_bytes as f64 / self.arena_bytes as f64
        }
    }
}

impl MemoryPlan {
    /// An unresolved plan (used while the executable is being built).
    pub fn unresolved(n_values: usize) -> Self {
        MemoryPlan { slots: vec![None; n_values], arena_elems: 0, total_value_elems: 0 }
    }

    pub fn stats(&self) -> ArenaStats {
        ArenaStats {
            arena_bytes: self.arena_elems * std::mem::size_of::<f32>(),
            value_bytes: self.total_value_elems * std::mem::size_of::<f32>(),
        }
    }

    /// Assign every materialized value an arena range with
    /// lifetime-disjoint reuse. Deterministic: values are placed in
    /// launch order, first-fit over a coalescing free list.
    pub fn compute(exe: &StitchedExecutable) -> MemoryPlan {
        let lives = liveness(exe);
        let mut slots: Vec<Option<BufSlot>> = vec![None; lives.len()];
        let mut free = FreeList::default();
        let mut total = 0usize;

        // Values sorted by def point, stable in id order within a point
        // — the same order `liveness` assigned defs, so placement is
        // reproducible across processes.
        let mut order: Vec<usize> = (0..lives.len()).filter(|&v| lives[v].is_some()).collect();
        order.sort_by_key(|&v| (lives[v].unwrap().def, v));

        // Sweep: before placing the defs of point `p`, release every
        // value whose last use is strictly before `p`.
        let mut expiring: Vec<usize> = order.clone();
        expiring.sort_by_key(|&v| (lives[v].unwrap().last_use, v));
        let mut expire_cursor = 0usize;
        for &v in &order {
            let life = lives[v].unwrap();
            while expire_cursor < expiring.len() {
                let e = expiring[expire_cursor];
                let el = lives[e].unwrap();
                if el.last_use >= life.def {
                    break;
                }
                if let Some(slot) = slots[e] {
                    free.release(slot.off, slot.elems);
                }
                expire_cursor += 1;
            }
            let off = free.alloc(life.elems);
            slots[v] = Some(BufSlot { off, elems: life.elems });
            total += life.elems;
        }

        MemoryPlan { slots, arena_elems: free.high_water(), total_value_elems: total }
    }
}

/// Lifetimes of every materialized value of `exe` over launch points.
/// Public so the test suite can assert that overlapping lifetimes never
/// share arena ranges.
pub fn liveness(exe: &StitchedExecutable) -> Vec<Option<ValueLife>> {
    let mut lives: Vec<Option<ValueLife>> = vec![None; exe.n_values];
    let mut define = |id: usize, elems: usize, point: usize| {
        lives[id] = Some(ValueLife { def: point, last_use: point, elems: elems.max(1) });
    };
    for p in &exe.params {
        define(p.id.0, p.elems, 0);
    }
    for &(id, elems) in &exe.consts {
        define(id.0, elems, 0);
    }
    for (li, launch) in exe.launches.iter().enumerate() {
        let point = li + 1;
        match launch {
            Launch::Kernel(k) => {
                for &(root, elems) in &k.outputs {
                    lives[root.0] =
                        Some(ValueLife { def: point, last_use: point, elems: elems.max(1) });
                }
                // Spill regions (global-tier stitching) are written and
                // read back within this launch only; the same-launch
                // `LoadGlobal` reads below keep `last_use == def`, so
                // the range retires immediately after the launch.
                for &(id, elems) in &k.spills {
                    lives[id.0] =
                        Some(ValueLife { def: point, last_use: point, elems: elems.max(1) });
                }
                for_each_kernel_read(k, |src| {
                    if let Some(life) = lives[src].as_mut() {
                        life.last_use = life.last_use.max(point);
                    }
                });
            }
            Launch::Library(l) => {
                lives[l.op.0] =
                    Some(ValueLife { def: point, last_use: point, elems: l.out_elems.max(1) });
                for r in library_reads(l) {
                    if let Some(life) = lives[r].as_mut() {
                        life.last_use = life.last_use.max(point);
                    }
                }
            }
        }
    }
    // The module result must survive to the end of the run.
    let end = exe.launches.len() + 1;
    if let Some(life) = lives[exe.root.0].as_mut() {
        life.last_use = end;
    }
    lives
}

/// Bake resolved [`BufSlot`]s into every per-element load and library
/// operand of `exe`, and store the computed plan on the executable.
/// Called once at the end of lowering.
pub fn resolve(exe: &mut StitchedExecutable) {
    let plan = MemoryPlan::compute(exe);
    for launch in &mut exe.launches {
        match launch {
            Launch::Kernel(k) => {
                for step in &mut k.steps {
                    if let BlockStep::Loop { kind, .. } = step {
                        match kind {
                            LoopKind::Map { prog } => resolve_prog(prog, &plan.slots),
                            LoopKind::Reduce { operand, .. } => resolve_prog(operand, &plan.slots),
                            LoopKind::Dot { lhs, rhs, .. } => {
                                resolve_prog(lhs, &plan.slots);
                                resolve_prog(rhs, &plan.slots);
                            }
                        }
                    }
                }
            }
            Launch::Library(l) => {
                l.out_slot = plan.slots[l.op.0];
                match &mut l.kind {
                    LibKind::Dot { lhs, rhs } => {
                        lhs.slot = plan.slots[lhs.src.0];
                        rhs.slot = plan.slots[rhs.src.0];
                    }
                    LibKind::Conv2d { input, filter } => {
                        input.slot = plan.slots[input.src.0];
                        filter.slot = plan.slots[filter.src.0];
                    }
                }
            }
        }
    }
    exe.mem = plan;
}

fn resolve_prog(prog: &mut ThreadProg, slots: &[Option<BufSlot>]) {
    for ins in &mut prog.code {
        match ins {
            TInstr::LoadGlobal { src, buf, .. } => *buf = slots[src.0],
            TInstr::LoadOwned { src, buf, .. } => *buf = slots[src.0],
            TInstr::Branch { cases, .. } => {
                for case in cases {
                    resolve_prog(case, slots);
                }
            }
            _ => {}
        }
    }
}

/// Every arena value a kernel launch reads: global loads plus
/// same-launch root reads (`LoadOwned` — the def and the use share the
/// launch point, which keeps the range live through the launch).
fn for_each_kernel_read(k: &super::bytecode::KernelProgram, mut f: impl FnMut(usize)) {
    fn walk(prog: &ThreadProg, f: &mut impl FnMut(usize)) {
        for ins in &prog.code {
            match ins {
                TInstr::LoadGlobal { src, .. } | TInstr::LoadOwned { src, .. } => f(src.0),
                TInstr::Branch { cases, .. } => {
                    for case in cases {
                        walk(case, f);
                    }
                }
                _ => {}
            }
        }
    }
    for step in &k.steps {
        if let BlockStep::Loop { kind, .. } = step {
            match kind {
                LoopKind::Map { prog } => walk(prog, &mut f),
                LoopKind::Reduce { operand, .. } => walk(operand, &mut f),
                LoopKind::Dot { lhs, rhs, .. } => {
                    walk(lhs, &mut f);
                    walk(rhs, &mut f);
                }
            }
        }
    }
}

fn library_reads(l: &LibraryCall) -> impl Iterator<Item = usize> {
    let (a, b) = match &l.kind {
        LibKind::Dot { lhs, rhs } => (lhs.src.0, rhs.src.0),
        LibKind::Conv2d { input, filter } => (input.src.0, filter.src.0),
    };
    [a, b].into_iter()
}

/// Deterministic first-fit allocator over one linear address space with
/// coalescing frees — the whole arena layout is a pure function of the
/// launch sequence.
#[derive(Debug, Default)]
struct FreeList {
    /// Disjoint free ranges `(off, len)`, sorted by offset, coalesced.
    free: Vec<(usize, usize)>,
    high: usize,
}

impl FreeList {
    fn alloc(&mut self, len: usize) -> usize {
        debug_assert!(len > 0);
        for i in 0..self.free.len() {
            let (off, flen) = self.free[i];
            if flen >= len {
                if flen == len {
                    self.free.remove(i);
                } else {
                    self.free[i] = (off + len, flen - len);
                }
                return off;
            }
        }
        let off = self.high;
        self.high += len;
        off
    }

    fn release(&mut self, off: usize, len: usize) {
        debug_assert!(len > 0);
        let i = self.free.partition_point(|&(o, _)| o < off);
        self.free.insert(i, (off, len));
        // Coalesce with the right neighbor, then the left.
        if i + 1 < self.free.len() && self.free[i].0 + self.free[i].1 == self.free[i + 1].0 {
            self.free[i].1 += self.free[i + 1].1;
            self.free.remove(i + 1);
        }
        if i > 0 && self.free[i - 1].0 + self.free[i - 1].1 == self.free[i].0 {
            self.free[i - 1].1 += self.free[i].1;
            self.free.remove(i);
        }
    }

    fn high_water(&self) -> usize {
        self.high
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::{compile_module, FusionMode, PipelineConfig};
    use crate::gpusim::DeviceConfig;
    use crate::hlo::instruction::ReduceKind;
    use crate::hlo::{GraphBuilder, Module, Shape};
    use crate::schedule::PerfLibrary;

    fn lower(module: &Module) -> StitchedExecutable {
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let compiled =
            compile_module(module, FusionMode::FusionStitching, &mut lib, &PipelineConfig::default())
                .unwrap();
        (*compiled.executable.expect("must lower")).clone()
    }

    #[test]
    fn free_list_first_fit_and_coalesce() {
        let mut fl = FreeList::default();
        let a = fl.alloc(10);
        let b = fl.alloc(20);
        let c = fl.alloc(5);
        assert_eq!((a, b, c), (0, 10, 30));
        assert_eq!(fl.high_water(), 35);
        fl.release(a, 10);
        fl.release(c, 5);
        // first fit prefers the lowest hole that fits
        assert_eq!(fl.alloc(8), 0);
        // release everything; coalescing must rebuild one hole
        fl.release(0, 8);
        fl.release(b, 20);
        assert_eq!(fl.free.len(), 1);
        assert_eq!(fl.free[0], (0, 35));
        assert_eq!(fl.alloc(35), 0);
        assert_eq!(fl.high_water(), 35);
    }

    #[test]
    fn overlapping_lifetimes_never_share_ranges() {
        // softmax-shaped chain: plenty of intermediates with staggered
        // lifetimes, so both reuse and overlap occur.
        let mut b = GraphBuilder::new("softmax");
        let x = b.param("x", Shape::f32(&[32, 64]));
        let m = b.reduce(x, &[1], ReduceKind::Max);
        let mb = b.broadcast(m, &[32, 64], &[0]);
        let sh = b.sub(x, mb);
        let e = b.exp(sh);
        let s = b.reduce(e, &[1], ReduceKind::Sum);
        let sb = b.broadcast(s, &[32, 64], &[0]);
        let o = b.div(e, sb);
        let module = Module::new("softmax", b.finish(o));
        let exe = lower(&module);

        let lives = liveness(&exe);
        let plan = &exe.mem;
        assert_eq!(plan.slots.len(), lives.len());
        for v in 0..lives.len() {
            let (Some(lv), Some(sv)) = (lives[v], plan.slots[v]) else { continue };
            assert_eq!(sv.elems, lv.elems);
            assert!(sv.off + sv.elems <= plan.arena_elems);
            for w in v + 1..lives.len() {
                let (Some(lw), Some(sw)) = (lives[w], plan.slots[w]) else { continue };
                if lv.overlaps(&lw) {
                    let disjoint = sv.off + sv.elems <= sw.off || sw.off + sw.elems <= sv.off;
                    assert!(
                        disjoint,
                        "values %{v} {lv:?}@{sv:?} and %{w} {lw:?}@{sw:?} overlap in time \
                         and share arena bytes"
                    );
                }
            }
        }
    }

    #[test]
    fn sequential_chain_reuses_retired_ranges() {
        // dot → tanh → dot → tanh → dot → tanh: library calls pin the
        // launch boundaries (elementwise fusion cannot collapse them),
        // and each stage's input dies as the next output is born — the
        // arena must stay well below the sum of all value sizes.
        let mut b = GraphBuilder::new("chain");
        let x = b.param("x", Shape::f32(&[64, 64]));
        let w = b.param("w", Shape::f32(&[64, 64]));
        let mut cur = x;
        for _ in 0..3 {
            let d = b.dot(cur, w);
            cur = b.tanh(d);
        }
        let module = Module::new("chain", b.finish(cur));
        let exe = lower(&module);
        let plan = &exe.mem;
        assert!(
            exe.launches.len() >= 6,
            "3 library calls + 3 kernels expected, got {}",
            exe.launches.len()
        );
        assert!(
            plan.arena_elems < plan.total_value_elems,
            "chain must reuse retired ranges: arena {} vs values {}",
            plan.arena_elems,
            plan.total_value_elems
        );
        assert!(plan.stats().reuse_ratio() > 1.5, "ratio = {}", plan.stats().reuse_ratio());
    }

    #[test]
    fn every_load_is_resolved() {
        let (_, module) = crate::models::by_name("LR").unwrap();
        let exe = lower(&module);
        for launch in &exe.launches {
            match launch {
                Launch::Kernel(k) => for_each_kernel_read(k, |src| {
                    assert!(exe.mem.slots[src].is_some(), "read of %{src} has no arena slot");
                }),
                Launch::Library(l) => {
                    assert!(l.out_slot.is_some());
                    for r in library_reads(l) {
                        assert!(exe.mem.slots[r].is_some());
                    }
                }
            }
        }
        // the root always has a slot, pinned live to the end
        let lives = liveness(&exe);
        let root_life = lives[exe.root.0].expect("root must be materialized");
        assert_eq!(root_life.last_use, exe.launches.len() + 1);
        assert!(exe.mem.slots[exe.root.0].is_some());
    }
}
