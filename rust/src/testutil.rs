//! Test utilities: a deterministic PRNG (this image has no `rand` /
//! `proptest`), random graph generators for property-style tests, and a
//! self-cleaning temp dir.
//!
//! The property tests in `rust/tests/` draw hundreds of random graphs
//! from [`GraphGen`] and assert pipeline invariants over each — the same
//! methodology proptest would give us, with an explicit seed for
//! reproducibility.

use crate::hlo::instruction::ReduceKind;
use crate::hlo::{Computation, GraphBuilder, InstrId, Shape};
use std::path::PathBuf;

/// xorshift64* — deterministic, seedable, good enough for test-case
/// generation.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, n)`.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in `[lo, hi]`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        (self.next_u64() as f64 / u64::MAX as f64) < p
    }

    /// Pick a random element.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    pub fn f64(&mut self) -> f64 {
        self.next_u64() as f64 / u64::MAX as f64
    }
}

/// Random-graph generator: builds well-formed computations mixing the
/// paper's four op categories, for property-style testing of the whole
/// pipeline.
pub struct GraphGen {
    pub rng: Rng,
    /// Max instructions per generated graph.
    pub max_ops: usize,
    /// Probability of emitting a library call (dot).
    pub p_library: f64,
}

impl GraphGen {
    pub fn new(seed: u64) -> Self {
        GraphGen { rng: Rng::new(seed), max_ops: 24, p_library: 0.08 }
    }

    /// Generate one random computation. All graphs are valid (built via
    /// the shape-inferring builder) and end in a single root.
    pub fn gen(&mut self) -> Computation {
        let rng = &mut self.rng;
        let mut b = GraphBuilder::new("prop");
        let base_dims: Vec<i64> = match rng.below(3) {
            0 => vec![rng.range(2, 8) as i64 * 2, rng.range(8, 64) as i64],
            1 => vec![
                rng.range(2, 4) as i64 * 2,
                rng.range(4, 16) as i64,
                rng.range(8, 32) as i64 * 2,
            ],
            _ => vec![rng.range(16, 256) as i64 * 2],
        };
        let p0 = b.param("p0", Shape::f32(&base_dims));
        let p1 = b.param("p1", Shape::f32(&base_dims));
        // pool of same-shape values we can combine elementwise
        let mut pool: Vec<InstrId> = vec![p0, p1];
        let mut last = p0;
        let n_ops = rng.range(3, self.max_ops);
        for _ in 0..n_ops {
            let v = *rng.pick(&pool);
            let w = *rng.pick(&pool);
            let dims = b.peek().get(v).shape.dims.clone();
            let rank = dims.len();
            let choice = rng.below(10);
            let out = match choice {
                0 => b.add(v, w),
                1 => b.mul(v, w),
                2 => b.exp(v),
                3 => b.tanh(v),
                4 => b.div(v, w),
                5 if rank >= 2 => {
                    // transpose then transpose back keeps shapes poolable
                    let mut perm: Vec<usize> = (0..rank).collect();
                    perm.swap(rank - 2, rank - 1);
                    let t = b.transpose(v, &perm);
                    b.transpose(t, &perm)
                }
                6 if rank >= 2 => {
                    // reduce minor dim then broadcast back
                    let r = b.reduce(v, &[rank - 1], ReduceKind::Sum);
                    let bdims: Vec<usize> = (0..rank - 1).collect();
                    b.broadcast(r, &dims, &bdims)
                }
                7 => {
                    let flat: i64 = dims.iter().product();
                    let r = b.reshape(v, &[flat]);
                    b.reshape(r, &dims)
                }
                8 => b.max(v, w),
                _ => b.sub(v, w),
            };
            pool.push(out);
            last = out;
        }
        // occasional library call at the end (LC-layer)
        if rng.chance(self.p_library) {
            let d = b.peek().get(last).shape.dims.clone();
            if d.len() == 2 {
                let wshape = Shape::f32(&[d[1], d[1]]);
                let wparam = b.param("w", wshape);
                last = b.dot(last, wparam);
            }
        }
        let t = b.tanh(last);
        b.finish(t)
    }
}

/// A temp directory removed on drop.
pub struct TempDir(PathBuf);

impl TempDir {
    pub fn new(tag: &str) -> Self {
        let mut p = std::env::temp_dir();
        let unique = format!(
            "fs-{tag}-{}-{:x}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        );
        p.push(unique);
        std::fs::create_dir_all(&p).expect("create temp dir");
        TempDir(p)
    }

    pub fn path(&self) -> &std::path::Path {
        &self.0
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::verifier::verify_computation;

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_range_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.range(3, 9);
            assert!((3..=9).contains(&v));
        }
    }

    #[test]
    fn generated_graphs_verify() {
        let mut g = GraphGen::new(123);
        for _ in 0..50 {
            let c = g.gen();
            verify_computation(&c).unwrap();
            assert!(c.len() >= 5);
        }
    }

    #[test]
    fn tempdir_cleans_up() {
        let p;
        {
            let d = TempDir::new("t");
            p = d.path().to_path_buf();
            std::fs::write(p.join("x"), "y").unwrap();
        }
        assert!(!p.exists());
    }
}
