//! Minimal hand-rolled JSON writer (the offline image carries no serde).
//!
//! One writer serves every JSON producer in the tree — the Chrome trace
//! exporter, the serving-stats serializer, and the bench harnesses — so
//! stats stop being formatted three different ways. The builder keeps a
//! comma-needed flag per open container; callers emit structurally
//! (begin/end + typed fields) and cannot produce a missing-comma or
//! trailing-comma document.

/// Streaming JSON builder. Values appended to an open object must go
/// through [`Json::key`] (or the `field_*` helpers); values appended to
/// an open array are written directly.
#[derive(Debug, Default)]
pub struct Json {
    buf: String,
    /// One entry per open container: `true` once the container holds at
    /// least one element (so the next element is comma-prefixed).
    stack: Vec<bool>,
    /// Set between a `key(..)` and its value: the value belongs to the
    /// key and must not be comma-prefixed again.
    pending_key: bool,
}

impl Json {
    pub fn new() -> Json {
        Json::default()
    }

    fn comma(&mut self) {
        if self.pending_key {
            self.pending_key = false;
            return;
        }
        if let Some(top) = self.stack.last_mut() {
            if *top {
                self.buf.push(',');
            } else {
                *top = true;
            }
        }
    }

    fn push_escaped(&mut self, s: &str) {
        self.buf.push('"');
        for c in s.chars() {
            match c {
                '"' => self.buf.push_str("\\\""),
                '\\' => self.buf.push_str("\\\\"),
                '\n' => self.buf.push_str("\\n"),
                '\r' => self.buf.push_str("\\r"),
                '\t' => self.buf.push_str("\\t"),
                c if (c as u32) < 0x20 => {
                    self.buf.push_str(&format!("\\u{:04x}", c as u32));
                }
                c => self.buf.push(c),
            }
        }
        self.buf.push('"');
    }

    pub fn begin_obj(&mut self) -> &mut Json {
        self.comma();
        self.buf.push('{');
        self.stack.push(false);
        self
    }

    pub fn end_obj(&mut self) -> &mut Json {
        self.stack.pop();
        self.buf.push('}');
        self
    }

    pub fn begin_arr(&mut self) -> &mut Json {
        self.comma();
        self.buf.push('[');
        self.stack.push(false);
        self
    }

    pub fn end_arr(&mut self) -> &mut Json {
        self.stack.pop();
        self.buf.push(']');
        self
    }

    pub fn key(&mut self, k: &str) -> &mut Json {
        self.comma();
        self.push_escaped(k);
        self.buf.push(':');
        self.pending_key = true;
        self
    }

    pub fn str_val(&mut self, s: &str) -> &mut Json {
        self.comma();
        self.push_escaped(s);
        self
    }

    /// Finite floats print via Rust's shortest round-trip `Display`
    /// (never exponent notation, always JSON-legal); non-finite values
    /// have no JSON spelling and degrade to 0.
    pub fn num(&mut self, v: f64) -> &mut Json {
        self.comma();
        if v.is_finite() {
            self.buf.push_str(&format!("{v}"));
        } else {
            self.buf.push('0');
        }
        self
    }

    pub fn int(&mut self, v: i64) -> &mut Json {
        self.comma();
        self.buf.push_str(&format!("{v}"));
        self
    }

    pub fn uint(&mut self, v: u64) -> &mut Json {
        self.comma();
        self.buf.push_str(&format!("{v}"));
        self
    }

    pub fn bool_val(&mut self, v: bool) -> &mut Json {
        self.comma();
        self.buf.push_str(if v { "true" } else { "false" });
        self
    }

    pub fn field_str(&mut self, k: &str, v: &str) -> &mut Json {
        self.key(k).str_val(v)
    }

    pub fn field_num(&mut self, k: &str, v: f64) -> &mut Json {
        self.key(k).num(v)
    }

    pub fn field_int(&mut self, k: &str, v: i64) -> &mut Json {
        self.key(k).int(v)
    }

    pub fn field_uint(&mut self, k: &str, v: u64) -> &mut Json {
        self.key(k).uint(v)
    }

    pub fn field_bool(&mut self, k: &str, v: bool) -> &mut Json {
        self.key(k).bool_val(v)
    }

    pub fn finish(self) -> String {
        debug_assert!(self.stack.is_empty(), "unbalanced JSON containers");
        self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn objects_arrays_and_commas() {
        let mut j = Json::new();
        j.begin_obj();
        j.field_str("name", "a\"b\\c\n");
        j.field_int("n", -3);
        j.field_uint("u", 7);
        j.field_bool("ok", true);
        j.key("xs").begin_arr();
        j.num(1.5).num(f64::NAN).uint(2);
        j.end_arr();
        j.key("inner").begin_obj();
        j.end_obj();
        j.end_obj();
        assert_eq!(
            j.finish(),
            "{\"name\":\"a\\\"b\\\\c\\n\",\"n\":-3,\"u\":7,\"ok\":true,\
             \"xs\":[1.5,0,2],\"inner\":{}}"
        );
    }

    #[test]
    fn floats_stay_json_legal() {
        let mut j = Json::new();
        j.begin_arr();
        j.num(0.25).num(10.0).num(f64::INFINITY);
        j.end_arr();
        assert_eq!(j.finish(), "[0.25,10,0]");
    }
}
