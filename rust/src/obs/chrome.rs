//! Chrome trace-event JSON exporter: renders a [`TraceSnapshot`] into
//! the `chrome://tracing` / Perfetto "JSON Object Format" — an object
//! with a `traceEvents` array of complete ("ph":"X") events, timestamps
//! and durations in microseconds. Workers map to tracks via `tid`.

use super::json::Json;
use super::profile::tier_label;
use super::recorder::TraceSnapshot;

/// Render `snap` as a Perfetto-loadable trace-event JSON document.
pub fn chrome_trace(snap: &TraceSnapshot) -> String {
    let mut workers: Vec<u32> = snap.events.iter().map(|e| e.worker).collect();
    workers.sort_unstable();
    workers.dedup();

    let mut j = Json::new();
    j.begin_obj();
    j.key("traceEvents").begin_arr();
    // name the per-worker tracks
    for &w in &workers {
        j.begin_obj();
        j.field_str("name", "thread_name");
        j.field_str("ph", "M");
        j.field_int("pid", 1);
        j.field_uint("tid", w as u64);
        j.key("args").begin_obj();
        j.field_str("name", &format!("worker-{w}"));
        j.end_obj();
        j.end_obj();
    }
    for e in &snap.events {
        j.begin_obj();
        j.field_str("name", e.name);
        j.field_str("cat", e.cat.label());
        j.field_str("ph", "X");
        j.field_num("ts", e.start_us);
        j.field_num("dur", e.dur_us);
        j.field_int("pid", 1);
        j.field_uint("tid", e.worker as u64);
        j.key("args").begin_obj();
        if e.fp != 0 {
            j.field_str("fp", &format!("{:016x}", e.fp));
        }
        if let Some(tier) = e.tier {
            j.field_str("tier", tier_label(tier));
        }
        if e.fences > 0 {
            j.field_uint("fences", e.fences as u64);
        }
        if e.barriers > 0 {
            j.field_uint("barriers", e.barriers as u64);
        }
        j.end_obj();
        j.end_obj();
    }
    j.end_arr();
    j.field_str("displayTimeUnit", "ms");
    j.key("otherData").begin_obj();
    j.field_uint("dropped_events", snap.dropped);
    j.end_obj();
    j.end_obj();
    j.finish()
}

#[cfg(all(test, feature = "trace"))]
mod tests {
    use super::super::recorder::{begin, install, record, SpanCat, TraceConfig, TraceSink};
    use super::*;

    #[test]
    fn renders_events_and_metadata() {
        let sink = TraceSink::new(TraceConfig::default());
        {
            let _g = install(&sink, 2, None);
            record(SpanCat::Compile, "cache-hit", 0, begin());
        }
        let text = chrome_trace(&sink.snapshot());
        assert!(text.starts_with("{\"traceEvents\":["));
        assert!(text.contains("\"name\":\"worker-2\""));
        assert!(text.contains("\"cat\":\"compile\""));
        assert!(text.contains("\"ph\":\"X\""));
        assert!(text.contains("\"displayTimeUnit\":\"ms\""));
        assert!(text.contains("\"dropped_events\":0"));
    }
}
