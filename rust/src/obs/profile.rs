//! Per-group kernel profile: measured launch times keyed by group
//! fingerprint, joined against the explore pass's modeled costs.
//!
//! Every stitched launch records its wall time under the fused group's
//! structural fingerprint (the same `xg{fp:016x}` identity the explore
//! pass memoizes modeled costs under, see
//! [`crate::fusion::group_fingerprint`]), so a profile snapshot can be
//! joined 1:1 with the cost model: the modeled-vs-measured divergence
//! report is the artifact a future feedback-directed autotuner consumes
//! (ROADMAP: "measured time replaces modeled time").

use std::collections::BTreeMap;
use std::fmt;
use std::sync::{Arc, Mutex};

use crate::coordinator::metrics::StreamingSummary;
use crate::exec::StitchTier;

/// Bound on distinct fingerprints per profile. Real modules have a few
/// dozen fused groups; the cap only guards against a pathological
/// many-module aggregate growing without bound.
pub const PROFILE_MAX_GROUPS: usize = 256;

/// Measured statistics for one fused group (one generated kernel).
#[derive(Debug, Clone)]
pub struct GroupProfile {
    /// Stitching tier the group's kernel executes at.
    pub tier: StitchTier,
    /// The explore pass's modeled execution time (µs); 0 when the group
    /// was never priced (e.g. cost-guided fusion disabled).
    pub modeled_us: f64,
    /// Measured wall time per launch, µs (bounded reservoir).
    pub measured_us: StreamingSummary,
    /// Total launches observed for this group.
    pub launches: u64,
    /// Grid fences executed across all launches (global tier only).
    pub fences: u64,
    /// Block barriers executed across all launches.
    pub barriers: u64,
}

impl GroupProfile {
    fn new(tier: StitchTier, modeled_us: f64) -> GroupProfile {
        GroupProfile {
            tier,
            modeled_us,
            measured_us: StreamingSummary::default(),
            launches: 0,
            fences: 0,
            barriers: 0,
        }
    }
}

/// One row of the modeled-vs-measured join.
#[derive(Debug, Clone)]
pub struct DivergenceRow {
    pub fp: u64,
    pub tier: StitchTier,
    pub launches: u64,
    /// Retained wall-clock samples backing the trimmed statistics below
    /// (bounded by the summary's reservoir, ≤ launches).
    pub samples: u64,
    pub modeled_us: f64,
    pub measured_mean_us: f64,
    /// measured / modeled (0 when either side is missing): >1 means the
    /// cost model is optimistic for this group, <1 pessimistic.
    pub ratio: f64,
    /// Outlier-trimmed min/median/max of the retained samples (the same
    /// trim the measured cost oracle applies), 0 when nothing launched.
    pub trimmed_min_us: f64,
    pub trimmed_p50_us: f64,
    pub trimmed_max_us: f64,
}

/// Bounded map of [`GroupProfile`]s keyed by group fingerprint.
///
/// Deterministically ordered (BTreeMap) so reports and serialized forms
/// are stable across runs.
#[derive(Debug, Clone, Default)]
pub struct KernelProfile {
    groups: BTreeMap<u64, GroupProfile>,
    dropped_groups: u64,
}

impl KernelProfile {
    /// Pre-register a group with its modeled cost at compile time, so
    /// the divergence join works even before the first launch.
    pub fn seed(&mut self, fp: u64, tier: StitchTier, modeled_us: f64) {
        if let Some(g) = self.groups.get_mut(&fp) {
            g.tier = tier;
            g.modeled_us = modeled_us;
            return;
        }
        if self.groups.len() >= PROFILE_MAX_GROUPS {
            self.dropped_groups += 1;
            return;
        }
        self.groups.insert(fp, GroupProfile::new(tier, modeled_us));
    }

    /// Record one measured launch of group `fp`.
    pub fn record_launch(
        &mut self,
        fp: u64,
        tier: StitchTier,
        modeled_us: f64,
        wall_us: f64,
        fences: u64,
        barriers: u64,
    ) {
        if !self.groups.contains_key(&fp) {
            if self.groups.len() >= PROFILE_MAX_GROUPS {
                self.dropped_groups += 1;
                return;
            }
            self.groups.insert(fp, GroupProfile::new(tier, modeled_us));
        }
        let g = self.groups.get_mut(&fp).expect("group present");
        g.measured_us.record_us(wall_us);
        g.launches += 1;
        g.fences += fences;
        g.barriers += barriers;
    }

    /// Fold `other` into `self` (stats aggregation across workers or
    /// models). Respects the group bound; collisions merge summaries.
    pub fn merge(&mut self, other: &KernelProfile) {
        for (fp, og) in &other.groups {
            match self.groups.get_mut(fp) {
                Some(g) => {
                    g.measured_us.merge(&og.measured_us);
                    g.launches += og.launches;
                    g.fences += og.fences;
                    g.barriers += og.barriers;
                    if g.modeled_us == 0.0 {
                        g.modeled_us = og.modeled_us;
                    }
                }
                None => {
                    if self.groups.len() >= PROFILE_MAX_GROUPS {
                        self.dropped_groups += 1;
                        continue;
                    }
                    self.groups.insert(*fp, og.clone());
                }
            }
        }
        self.dropped_groups += other.dropped_groups;
    }

    pub fn len(&self) -> usize {
        self.groups.len()
    }

    pub fn is_empty(&self) -> bool {
        self.groups.is_empty()
    }

    /// Groups dropped because the [`PROFILE_MAX_GROUPS`] bound was hit.
    pub fn dropped_groups(&self) -> u64 {
        self.dropped_groups
    }

    /// Fingerprint-ordered iteration over the profiled groups.
    pub fn groups(&self) -> impl Iterator<Item = (u64, &GroupProfile)> {
        self.groups.iter().map(|(fp, g)| (*fp, g))
    }

    /// Total measured launches across all groups — reconciles with
    /// `LaunchLedger::generated` on the stitched path.
    pub fn total_launches(&self) -> u64 {
        self.groups.values().map(|g| g.launches).sum()
    }

    /// The modeled-vs-measured join, worst divergence first (largest
    /// `|ratio - 1|`; ties and unjoined rows — never launched or never
    /// priced, ratio 0 — order by fingerprint, unjoined last). Groups
    /// that never launched report a 0 measured mean and ratio.
    pub fn divergence(&self) -> Vec<DivergenceRow> {
        let mut rows: Vec<DivergenceRow> = self
            .groups
            .iter()
            .map(|(fp, g)| {
                let measured = g.measured_us.mean_us();
                let ratio = if g.modeled_us > 0.0 && g.launches > 0 {
                    measured / g.modeled_us
                } else {
                    0.0
                };
                let samples = g.measured_us.samples();
                let (trimmed_min_us, trimmed_p50_us, trimmed_max_us) =
                    crate::coordinator::metrics::trimmed_stats(samples);
                DivergenceRow {
                    fp: *fp,
                    tier: g.tier,
                    launches: g.launches,
                    samples: samples.len() as u64,
                    modeled_us: g.modeled_us,
                    measured_mean_us: measured,
                    ratio,
                    trimmed_min_us,
                    trimmed_p50_us,
                    trimmed_max_us,
                }
            })
            .collect();
        rows.sort_by(|a, b| {
            // Unjoined rows (ratio 0) sink below every real divergence.
            let key = |r: &DivergenceRow| if r.ratio > 0.0 { (r.ratio - 1.0).abs() } else { -1.0 };
            key(b)
                .partial_cmp(&key(a))
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.fp.cmp(&b.fp))
        });
        rows
    }

    /// Serialize with the shared JSON writer (stable, fp-ordered).
    pub fn write_json(&self, j: &mut super::json::Json) {
        j.begin_obj();
        j.field_uint("groups", self.groups.len() as u64);
        j.field_uint("dropped_groups", self.dropped_groups);
        j.key("divergence").begin_arr();
        for row in self.divergence() {
            j.begin_obj();
            j.field_str("fp", &format!("{:016x}", row.fp));
            j.field_str("tier", tier_label(row.tier));
            j.field_uint("launches", row.launches);
            j.field_uint("samples", row.samples);
            j.field_num("modeled_us", row.modeled_us);
            j.field_num("measured_mean_us", row.measured_mean_us);
            j.field_num("ratio", row.ratio);
            j.field_num("trimmed_min_us", row.trimmed_min_us);
            j.field_num("trimmed_p50_us", row.trimmed_p50_us);
            j.field_num("trimmed_max_us", row.trimmed_max_us);
            j.end_obj();
        }
        j.end_arr();
        j.end_obj();
    }
}

/// Stable lowercase label for a stitching tier (spans, exports, docs).
pub fn tier_label(tier: StitchTier) -> &'static str {
    match tier {
        StitchTier::Plain => "plain",
        StitchTier::Shm => "shm",
        StitchTier::Global => "global",
    }
}

/// Shared handle to a [`KernelProfile`], carried on
/// [`crate::coordinator::pipeline::CompiledModule`] and cloned into the
/// serving workers: every executor of the same compiled module feeds
/// the same profile. The mutex is uncontended in practice (one lock per
/// kernel launch, microseconds apart).
#[derive(Clone, Default)]
pub struct KernelProfileHandle(Arc<Mutex<KernelProfile>>);

impl KernelProfileHandle {
    pub fn new() -> KernelProfileHandle {
        KernelProfileHandle::default()
    }

    pub fn seed(&self, fp: u64, tier: StitchTier, modeled_us: f64) {
        self.0.lock().expect("profile lock poisoned").seed(fp, tier, modeled_us);
    }

    pub fn record_launch(
        &self,
        fp: u64,
        tier: StitchTier,
        modeled_us: f64,
        wall_us: f64,
        fences: u64,
        barriers: u64,
    ) {
        self.0
            .lock()
            .expect("profile lock poisoned")
            .record_launch(fp, tier, modeled_us, wall_us, fences, barriers);
    }

    /// Owned copy of the current profile state.
    pub fn snapshot(&self) -> KernelProfile {
        self.0.lock().expect("profile lock poisoned").clone()
    }

    /// Fold another profile's groups into this handle (the CLI's
    /// aggregate view across models — see [`KernelProfile::merge`]).
    pub fn merge_from(&self, other: &KernelProfile) {
        self.0.lock().expect("profile lock poisoned").merge(other);
    }
}

impl fmt::Debug for KernelProfileHandle {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let p = self.0.lock().expect("profile lock poisoned");
        write!(f, "KernelProfileHandle({} groups, {} launches)", p.len(), p.total_launches())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seed_then_record_joins_modeled_and_measured() {
        let p = KernelProfileHandle::new();
        p.seed(0xabc, StitchTier::Shm, 10.0);
        p.record_launch(0xabc, StitchTier::Shm, 10.0, 25.0, 2, 8);
        p.record_launch(0xabc, StitchTier::Shm, 10.0, 15.0, 2, 8);
        let snap = p.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap.total_launches(), 2);
        let rows = snap.divergence();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].fp, 0xabc);
        assert_eq!(rows[0].launches, 2);
        assert!((rows[0].measured_mean_us - 20.0).abs() < 1e-9);
        assert!((rows[0].ratio - 2.0).abs() < 1e-9);
        let snap2 = p.snapshot();
        let g = snap2.groups().next().expect("one group").1;
        assert_eq!((g.fences, g.barriers), (4, 16));
    }

    #[test]
    fn group_bound_counts_drops() {
        let mut p = KernelProfile::default();
        for fp in 0..(PROFILE_MAX_GROUPS as u64 + 5) {
            p.record_launch(fp, StitchTier::Plain, 1.0, 1.0, 0, 0);
        }
        assert_eq!(p.len(), PROFILE_MAX_GROUPS);
        assert_eq!(p.dropped_groups(), 5);
    }

    #[test]
    fn divergence_sorts_worst_first_with_unjoined_last() {
        let mut p = KernelProfile::default();
        p.record_launch(2, StitchTier::Plain, 9.0, 9.0, 0, 0); // ratio 1.0
        p.record_launch(1, StitchTier::Plain, 2.0, 5.0, 0, 0); // ratio 2.5
        p.seed(3, StitchTier::Plain, 4.0); // never launched → last
        let rows = p.divergence();
        assert_eq!(rows.iter().map(|r| r.fp).collect::<Vec<_>>(), vec![1, 2, 3]);
        assert_eq!(rows[0].samples, 1);
        assert!((rows[0].trimmed_min_us - 5.0).abs() < 1e-9);
        assert!((rows[0].trimmed_p50_us - 5.0).abs() < 1e-9);
        assert!((rows[0].trimmed_max_us - 5.0).abs() < 1e-9);
        assert_eq!(rows[2].samples, 0);
        assert_eq!(rows[2].trimmed_p50_us, 0.0);
    }

    #[test]
    fn merge_accumulates_groups() {
        let mut a = KernelProfile::default();
        a.record_launch(1, StitchTier::Plain, 2.0, 4.0, 0, 1);
        let mut b = KernelProfile::default();
        b.record_launch(1, StitchTier::Plain, 2.0, 6.0, 0, 1);
        b.record_launch(2, StitchTier::Global, 9.0, 9.0, 3, 0);
        a.merge(&b);
        assert_eq!(a.len(), 2);
        assert_eq!(a.total_launches(), 3);
        let rows = a.divergence();
        assert!((rows[0].measured_mean_us - 5.0).abs() < 1e-9);
        assert_eq!(rows[1].tier, StitchTier::Global);
    }
}
