//! Observability layer: flight recorder, per-launch kernel profiler,
//! and exporters.
//!
//! The paper's pipeline *models* cost (explore pass, PR 4) and the VM
//! *counts* launches ([`crate::exec::LaunchLedger`]), but nothing
//! measured where a served request's wall time actually went. This
//! module closes that gap:
//!
//! - [`recorder`] — the [`TraceSink`] flight recorder: bounded
//!   per-worker ring buffers of span events covering the whole request
//!   life cycle (queue → batch → compile/passes → launch → reply), with
//!   a thread-local install/record API so instrumentation sites stay
//!   one line.
//! - [`profile`] — [`KernelProfile`]: measured per-fused-group launch
//!   times keyed by group fingerprint, joined against the explore
//!   pass's modeled costs into a divergence report (the input the
//!   ROADMAP's feedback-directed autotuning item needs).
//! - [`chrome`] — Chrome trace-event JSON export (Perfetto-loadable).
//! - [`prom`] — Prometheus text exposition of every serving counter.
//! - [`json`] — the one hand-rolled JSON writer shared by exporters,
//!   stats serialization, and bench harnesses.
//!
//! Disable the `trace` cargo feature to compile the record path out
//! entirely; at runtime, [`TraceSink::set_enabled`] gates recording and
//! an uninstalled thread never reads the clock.

pub mod chrome;
pub mod json;
pub mod profile;
pub mod prom;
pub mod recorder;

pub use chrome::chrome_trace;
pub use json::Json;
pub use profile::{tier_label, DivergenceRow, GroupProfile, KernelProfile, KernelProfileHandle};
pub use prom::prometheus;
pub use recorder::{
    active, begin, install, launch, record, record_between, record_passes, set_profile, ObsGuard,
    SpanCat, SpanEvent, SpanTimer, TraceConfig, TraceSink, TraceSnapshot, WorkerRing,
};
