//! Prometheus text-exposition exporter: renders every counter the
//! serving stack already owns (launch tiers, cache hits/misses/cold
//! compiles, arena reuse, latency percentiles, per-group profile) in
//! the `# TYPE`-annotated text format a Prometheus scrape endpoint (or
//! a human) reads directly.

use std::fmt::Write as _;

use super::profile::tier_label;
use crate::coordinator::metrics::StreamingSummary;
use crate::coordinator::pool::ServingStats;

fn header(out: &mut String, name: &str, kind: &str, help: &str) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
}

fn line(out: &mut String, name: &str, labels: &str, v: f64) {
    if v.is_finite() {
        let _ = writeln!(out, "{name}{labels} {v}");
    } else {
        let _ = writeln!(out, "{name}{labels} 0");
    }
}

fn summary(out: &mut String, name: &str, help: &str, s: &StreamingSummary) {
    header(out, name, "summary", help);
    let qs = s.percentiles_us(&[50.0, 95.0, 99.0]);
    line(out, name, "{quantile=\"0.5\"}", qs[0]);
    line(out, name, "{quantile=\"0.95\"}", qs[1]);
    line(out, name, "{quantile=\"0.99\"}", qs[2]);
    line(out, &format!("{name}_sum"), "", s.sum_us());
    line(out, &format!("{name}_count"), "", s.count() as f64);
}

/// Render a full exposition document for `stats`. `dropped_events` is
/// the flight recorder's overflow counter when a sink was attached.
pub fn prometheus(stats: &ServingStats, dropped_events: Option<u64>) -> String {
    let mut out = String::new();
    let a = &stats.aggregate;

    header(&mut out, "fusion_workers", "gauge", "Serving workers in the pool.");
    line(&mut out, "fusion_workers", "", stats.per_worker.len().max(1) as f64);

    header(&mut out, "fusion_requests_total", "counter", "Requests served.");
    line(&mut out, "fusion_requests_total", "", a.requests as f64);
    header(&mut out, "fusion_batches_total", "counter", "Batches executed.");
    line(&mut out, "fusion_batches_total", "", a.batches as f64);
    header(&mut out, "fusion_stitched_batches_total", "counter", "Batches run on the stitched VM.");
    line(&mut out, "fusion_stitched_batches_total", "", a.stitched_batches as f64);
    header(&mut out, "fusion_rejected_total", "counter", "Requests rejected, by reason.");
    line(&mut out, "fusion_rejected_total", "{reason=\"oversized\"}", a.rejects.oversized as f64);
    line(&mut out, "fusion_rejected_total", "{reason=\"bucket_mismatch\"}", a.rejects.bucket_mismatch as f64);
    line(&mut out, "fusion_rejected_total", "{reason=\"deadline\"}", a.rejects.deadline as f64);
    line(&mut out, "fusion_rejected_total", "{reason=\"shed\"}", a.rejects.shed as f64);
    line(&mut out, "fusion_rejected_total", "{reason=\"compile_failed\"}", a.rejects.compile_failed as f64);
    header(&mut out, "fusion_deadline_misses_total", "counter", "Served requests that replied after their deadline.");
    line(&mut out, "fusion_deadline_misses_total", "", a.deadline_misses as f64);
    header(&mut out, "fusion_compile_failures_total", "counter", "Pipeline compiles that failed.");
    line(&mut out, "fusion_compile_failures_total", "", a.compile_failures as f64);

    header(&mut out, "fusion_queue_depth", "gauge", "Requests queued per shard, awaiting drain.");
    for (shard, depth) in stats.queue_depths.iter().enumerate() {
        line(&mut out, "fusion_queue_depth", &format!("{{shard=\"{shard}\"}}"), *depth as f64);
    }
    header(&mut out, "fusion_worker_respawns_total", "counter", "Workers respawned after a contained panic.");
    line(&mut out, "fusion_worker_respawns_total", "", stats.respawns as f64);
    header(&mut out, "fusion_reroutes_total", "counter", "Submissions rerouted past a down shard.");
    line(&mut out, "fusion_reroutes_total", "", stats.reroutes as f64);
    header(&mut out, "fusion_shards_down", "gauge", "Shards currently without a live worker.");
    line(&mut out, "fusion_shards_down", "", stats.shards_down as f64);
    if let Some(fast) = stats.compile_fast_fails {
        header(&mut out, "fusion_compile_fast_fails_total", "counter", "Compiles answered by the negative cache's backoff.");
        line(&mut out, "fusion_compile_fast_fails_total", "", fast as f64);
    }

    header(&mut out, "fusion_padded_elems_total", "counter", "Pad elements appended to reach bucket canonical lengths.");
    line(&mut out, "fusion_padded_elems_total", "", a.padded_elems as f64);
    header(&mut out, "fusion_live_elems_total", "counter", "Caller-supplied elements carried in occupied batch rows.");
    line(&mut out, "fusion_live_elems_total", "", a.live_elems as f64);
    header(&mut out, "fusion_padding_waste_ratio", "gauge", "padded / (padded + live) elements across occupied rows.");
    line(&mut out, "fusion_padding_waste_ratio", "", a.padding_waste_ratio());

    header(&mut out, "fusion_launches_total", "counter", "Kernel launches by kind.");
    line(&mut out, "fusion_launches_total", "{kind=\"generated\"}", a.launches.generated as f64);
    line(&mut out, "fusion_launches_total", "{kind=\"library\"}", a.launches.library as f64);
    header(&mut out, "fusion_launch_tier_total", "counter", "Generated launches by stitch tier.");
    line(&mut out, "fusion_launch_tier_total", "{tier=\"plain\"}", a.launches.tier_plain as f64);
    line(&mut out, "fusion_launch_tier_total", "{tier=\"shm\"}", a.launches.tier_shm as f64);
    line(&mut out, "fusion_launch_tier_total", "{tier=\"global\"}", a.launches.tier_global as f64);
    header(&mut out, "fusion_launch_barriers_total", "counter", "Block barriers executed.");
    line(&mut out, "fusion_launch_barriers_total", "", a.launches.barriers as f64);
    header(&mut out, "fusion_launch_fences_total", "counter", "Grid fences executed.");
    line(&mut out, "fusion_launch_fences_total", "", a.launches.fences as f64);

    header(&mut out, "fusion_worker_cache_hits_total", "counter", "Worker-observed compile cache hits.");
    line(&mut out, "fusion_worker_cache_hits_total", "", a.cache_hits as f64);
    header(&mut out, "fusion_worker_cache_misses_total", "counter", "Worker-observed compile cache misses.");
    line(&mut out, "fusion_worker_cache_misses_total", "", a.cache_misses as f64);
    if let Some(cache) = &stats.cache {
        header(&mut out, "fusion_compile_cache_hits_total", "counter", "Shared compile cache hits.");
        line(&mut out, "fusion_compile_cache_hits_total", "", cache.hits as f64);
        header(&mut out, "fusion_compile_cache_misses_total", "counter", "Shared compile cache misses.");
        line(&mut out, "fusion_compile_cache_misses_total", "", cache.misses as f64);
        header(&mut out, "fusion_compile_cache_evictions_total", "counter", "Shared compile cache evictions.");
        line(&mut out, "fusion_compile_cache_evictions_total", "", cache.evictions as f64);
        header(&mut out, "fusion_compile_cache_insertions_total", "counter", "Shared compile cache insertions.");
        line(&mut out, "fusion_compile_cache_insertions_total", "", cache.insertions as f64);
    }
    if let Some(cold) = stats.cold_compiles {
        header(&mut out, "fusion_cold_compiles_total", "counter", "Full pipeline compiles (single-flight).");
        line(&mut out, "fusion_cold_compiles_total", "", cold as f64);
    }

    header(&mut out, "fusion_arena_reuses_total", "counter", "Allocation-free arena reuses.");
    line(&mut out, "fusion_arena_reuses_total", "", a.arena_reuses as f64);
    if let Some(arena) = &a.arena {
        header(&mut out, "fusion_arena_bytes", "gauge", "Planned arena high-water mark, bytes.");
        line(&mut out, "fusion_arena_bytes", "", arena.arena_bytes as f64);
        header(&mut out, "fusion_arena_value_bytes", "gauge", "Unreused value footprint, bytes.");
        line(&mut out, "fusion_arena_value_bytes", "", arena.value_bytes as f64);
        header(&mut out, "fusion_arena_reuse_ratio", "gauge", "value_bytes / arena_bytes.");
        line(&mut out, "fusion_arena_reuse_ratio", "", arena.reuse_ratio());
    }

    summary(&mut out, "fusion_exec_latency_us", "Per-batch execution latency, µs.", &a.exec_us);
    summary(&mut out, "fusion_compile_latency_us", "Compile (cache lookup or cold) latency, µs.", &a.compile_us);
    summary(&mut out, "fusion_queue_latency_us", "Request queue wait, µs.", &a.queue_us);
    summary(&mut out, "fusion_slack_us", "Signed per-request slack at reply time, µs.", &a.slack_us);

    if let Some(dropped) = dropped_events {
        header(&mut out, "fusion_trace_dropped_events_total", "counter", "Flight-recorder ring overflow drops.");
        line(&mut out, "fusion_trace_dropped_events_total", "", dropped as f64);
    }

    if let Some(profile) = &a.profile {
        let snap = profile.snapshot();
        if !snap.is_empty() {
            header(&mut out, "fusion_group_launches_total", "counter", "Measured launches per fused group.");
            for (fp, g) in snap.groups() {
                let labels = format!("{{fp=\"{:016x}\",tier=\"{}\"}}", fp, tier_label(g.tier));
                line(&mut out, "fusion_group_launches_total", &labels, g.launches as f64);
            }
            header(&mut out, "fusion_group_measured_us_mean", "gauge", "Measured mean launch wall time per fused group, µs.");
            header(&mut out, "fusion_group_modeled_us", "gauge", "Explore-pass modeled launch time per fused group, µs.");
            for (fp, g) in snap.groups() {
                let labels = format!("{{fp=\"{:016x}\",tier=\"{}\"}}", fp, tier_label(g.tier));
                line(&mut out, "fusion_group_measured_us_mean", &labels, g.measured_us.mean_us());
                line(&mut out, "fusion_group_modeled_us", &labels, g.modeled_us);
            }
        }
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::WorkerStats;

    #[test]
    fn exposition_covers_core_counter_families() {
        let mut w = WorkerStats::default();
        w.requests = 12;
        w.batches = 3;
        w.launches.generated = 6;
        w.launches.tier_plain = 4;
        w.launches.tier_shm = 2;
        w.exec_us.record_us(100.0);
        w.queue_us.record_us(5.0);
        w.padded_elems = 3;
        w.live_elems = 9;
        w.rejected = 3;
        w.rejects.oversized = 1;
        w.rejects.deadline = 2;
        w.deadline_misses = 1;
        w.slack_us.record_us(250.0);
        let stats = ServingStats {
            per_worker: vec![w.clone()],
            aggregate: w,
            cache: None,
            cold_compiles: None,
            generation: None,
            respawns: 1,
            reroutes: 4,
            queue_depths: vec![2, 0],
            shards_down: 1,
            compile_fast_fails: Some(5),
        };
        let text = prometheus(&stats, Some(0));
        for family in [
            "fusion_requests_total 12",
            "fusion_launches_total{kind=\"generated\"} 6",
            "fusion_launch_tier_total{tier=\"plain\"} 4",
            "fusion_arena_reuses_total 0",
            "fusion_padded_elems_total 3",
            "fusion_live_elems_total 9",
            "fusion_padding_waste_ratio 0.25",
            "fusion_exec_latency_us{quantile=\"0.5\"} 100",
            "fusion_queue_latency_us_count 1",
            "fusion_trace_dropped_events_total 0",
            "# TYPE fusion_launch_tier_total counter",
            "fusion_rejected_total{reason=\"oversized\"} 1",
            "fusion_rejected_total{reason=\"deadline\"} 2",
            "fusion_rejected_total{reason=\"bucket_mismatch\"} 0",
            "fusion_rejected_total{reason=\"shed\"} 0",
            "fusion_rejected_total{reason=\"compile_failed\"} 0",
            "fusion_deadline_misses_total 1",
            "fusion_queue_depth{shard=\"0\"} 2",
            "fusion_queue_depth{shard=\"1\"} 0",
            "fusion_worker_respawns_total 1",
            "fusion_reroutes_total 4",
            "fusion_shards_down 1",
            "fusion_compile_fast_fails_total 5",
            "fusion_slack_us_count 1",
            "# TYPE fusion_queue_depth gauge",
            "# TYPE fusion_rejected_total counter",
        ] {
            assert!(text.contains(family), "missing {family:?} in:\n{text}");
        }
    }
}
