//! The flight recorder: a lock-light, bounded trace sink with
//! per-worker ring buffers of timestamped span events.
//!
//! Design constraints (ROADMAP observability item):
//! - **bounded**: each worker owns a fixed-capacity ring; overflow
//!   drops the oldest event and counts it, so a long-lived server keeps
//!   O(workers × capacity) memory no matter how much it serves.
//! - **zero-allocation record path**: ring storage is reserved at
//!   registration; recording a span copies one POD [`SpanEvent`] into
//!   the ring under a per-worker mutex that only that worker contends.
//! - **~0 overhead when off**: instrumentation sites call
//!   [`begin`], which reads a thread-local and takes no timestamp when
//!   no recorder is installed (or the sink is disabled); the whole
//!   record path additionally compiles to nothing without the `trace`
//!   cargo feature.
//!
//! Instrumentation is context-based: a worker thread [`install`]s a
//! sink + worker id once, and every layer below it (batcher, compile
//! cache, stitched VM) records through free functions without plumbing
//! a recorder argument through the call tree.

use std::cell::RefCell;
use std::fmt;
use std::marker::PhantomData;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::profile::KernelProfileHandle;
use crate::coordinator::metrics::PassRecord;
use crate::exec::{LaunchLedger, StitchTier};

/// Whether the record path is compiled in at all. With
/// `--no-default-features` every record function is statically dead and
/// the instrumentation sites cost nothing.
const TRACE_COMPILED: bool = cfg!(feature = "trace");

/// Span taxonomy: one category per stage of a request's life, plus
/// compile-pass child spans.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SpanCat {
    /// Request sat in the worker's queue (enqueue → batch drain).
    Queue,
    /// Batch tensor assembly from request rows.
    Batch,
    /// Compile-cache lookup / cold pipeline compile.
    Compile,
    /// One pipeline pass inside a cold compile (PassTrace child span).
    Pass,
    /// One kernel or library launch on the VM / interpreter.
    Launch,
    /// Result slicing + reply send.
    Reply,
}

impl SpanCat {
    pub const ALL: [SpanCat; 6] = [
        SpanCat::Queue,
        SpanCat::Batch,
        SpanCat::Compile,
        SpanCat::Pass,
        SpanCat::Launch,
        SpanCat::Reply,
    ];

    pub fn label(self) -> &'static str {
        match self {
            SpanCat::Queue => "queue",
            SpanCat::Batch => "batch",
            SpanCat::Compile => "compile",
            SpanCat::Pass => "pass",
            SpanCat::Launch => "launch",
            SpanCat::Reply => "reply",
        }
    }
}

/// One recorded span. POD (`Copy`) so the ring record path is a plain
/// slot write.
#[derive(Debug, Clone, Copy)]
pub struct SpanEvent {
    pub cat: SpanCat,
    /// Static span name ("cache-hit", "shm", "fusion", ...). Static so
    /// recording never allocates.
    pub name: &'static str,
    /// Worker/shard id that recorded the span.
    pub worker: u32,
    /// Start offset from the sink epoch, µs.
    pub start_us: f64,
    pub dur_us: f64,
    /// Fused-group fingerprint for launch spans (0 when not applicable).
    pub fp: u64,
    /// Stitching tier for generated-kernel launch spans.
    pub tier: Option<StitchTier>,
    /// Grid fences executed during this launch.
    pub fences: u32,
    /// Block barriers executed during this launch.
    pub barriers: u32,
}

/// Sink construction parameters.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Record events right away (a disabled sink still installs, so a
    /// profile can collect without tracing).
    pub enabled: bool,
    /// Ring capacity per worker, in events (clamped to ≥ 1).
    pub capacity_per_worker: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { enabled: true, capacity_per_worker: 16 * 1024 }
    }
}

/// Fixed-capacity drop-oldest event ring.
struct RingBuf {
    buf: Vec<SpanEvent>,
    /// Oldest slot once the ring is full; next slot to overwrite.
    head: usize,
    cap: usize,
}

/// One worker's ring plus its dropped-event counter.
pub struct WorkerRing {
    worker: u32,
    dropped: AtomicU64,
    inner: Mutex<RingBuf>,
}

impl WorkerRing {
    fn new(worker: u32, cap: usize) -> WorkerRing {
        let cap = cap.max(1);
        WorkerRing {
            worker,
            dropped: AtomicU64::new(0),
            inner: Mutex::new(RingBuf { buf: Vec::with_capacity(cap), head: 0, cap }),
        }
    }

    pub fn worker(&self) -> u32 {
        self.worker
    }

    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    fn push(&self, ev: SpanEvent) {
        let mut ring = self.inner.lock().expect("trace ring poisoned");
        if ring.buf.len() < ring.cap {
            // still within the reservation made at registration: this
            // push cannot reallocate
            ring.buf.push(ev);
        } else {
            let h = ring.head;
            ring.buf[h] = ev;
            ring.head = (h + 1) % ring.cap;
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Events oldest-first.
    fn drain_ordered(&self, out: &mut Vec<SpanEvent>) {
        let ring = self.inner.lock().expect("trace ring poisoned");
        out.extend_from_slice(&ring.buf[ring.head..]);
        out.extend_from_slice(&ring.buf[..ring.head]);
    }
}

/// Point-in-time copy of everything the sink holds.
#[derive(Debug, Clone, Default)]
pub struct TraceSnapshot {
    /// All events, grouped by worker id, oldest-first within a worker.
    pub events: Vec<SpanEvent>,
    /// Events lost to ring overflow across all workers.
    pub dropped: u64,
}

impl TraceSnapshot {
    pub fn count_by_cat(&self, cat: SpanCat) -> usize {
        self.events.iter().filter(|e| e.cat == cat).count()
    }

    /// Generated-kernel launch spans per tier: (plain, shm, global) —
    /// reconciles with the `LaunchLedger` tier counters.
    pub fn launch_tier_counts(&self) -> (u64, u64, u64) {
        let mut counts = (0u64, 0u64, 0u64);
        for e in &self.events {
            match e.tier {
                Some(StitchTier::Plain) => counts.0 += 1,
                Some(StitchTier::Shm) => counts.1 += 1,
                Some(StitchTier::Global) => counts.2 += 1,
                None => {}
            }
        }
        counts
    }
}

/// The flight recorder. Create once, share (`Arc`) with every worker;
/// each worker registers its own ring so the hot record path never
/// touches a global lock.
pub struct TraceSink {
    enabled: AtomicBool,
    epoch: Instant,
    capacity: usize,
    rings: Mutex<Vec<Arc<WorkerRing>>>,
}

impl TraceSink {
    pub fn new(cfg: TraceConfig) -> Arc<TraceSink> {
        Arc::new(TraceSink {
            enabled: AtomicBool::new(cfg.enabled),
            epoch: Instant::now(),
            capacity: cfg.capacity_per_worker.max(1),
            rings: Mutex::new(Vec::new()),
        })
    }

    pub fn enabled(&self) -> bool {
        TRACE_COMPILED && self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Get-or-create the ring for `worker`. Threads sharing a worker id
    /// share a ring (and its drop counter).
    pub fn ring(&self, worker: u32) -> Arc<WorkerRing> {
        let mut rings = self.rings.lock().expect("trace sink poisoned");
        if let Some(r) = rings.iter().find(|r| r.worker == worker) {
            return r.clone();
        }
        let r = Arc::new(WorkerRing::new(worker, self.capacity));
        rings.push(r.clone());
        r
    }

    /// Total events lost to ring overflow.
    pub fn dropped_events(&self) -> u64 {
        let rings = self.rings.lock().expect("trace sink poisoned");
        rings.iter().map(|r| r.dropped()).sum()
    }

    pub fn snapshot(&self) -> TraceSnapshot {
        let mut rings: Vec<Arc<WorkerRing>> =
            self.rings.lock().expect("trace sink poisoned").clone();
        rings.sort_by_key(|r| r.worker);
        let mut snap = TraceSnapshot::default();
        for r in &rings {
            r.drain_ordered(&mut snap.events);
            snap.dropped += r.dropped();
        }
        snap
    }
}

impl fmt::Debug for TraceSink {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "TraceSink(enabled: {}, workers: {}, capacity: {})",
            self.enabled(),
            self.rings.lock().map(|r| r.len()).unwrap_or(0),
            self.capacity
        )
    }
}

/// What one thread records into: its sink, its ring, and (optionally)
/// the kernel profile of the module it is executing.
struct ObsCtx {
    sink: Arc<TraceSink>,
    ring: Arc<WorkerRing>,
    profile: Option<KernelProfileHandle>,
}

thread_local! {
    static CTX: RefCell<Option<ObsCtx>> = RefCell::new(None);
}

/// Uninstalls (restores the previous context) on drop. `!Send`: must be
/// dropped on the installing thread.
#[must_use = "dropping the guard uninstalls the recorder"]
pub struct ObsGuard {
    prev: Option<ObsCtx>,
    _not_send: PhantomData<*const ()>,
}

impl Drop for ObsGuard {
    fn drop(&mut self) {
        let prev = self.prev.take();
        CTX.with(|c| *c.borrow_mut() = prev);
    }
}

/// Install `sink` as this thread's recorder under worker id `worker`,
/// optionally attaching a kernel profile. Layers below the caller
/// (batcher, compile cache, VM) then record through the free functions
/// here. Returns a guard that restores the previous context.
pub fn install(
    sink: &Arc<TraceSink>,
    worker: u32,
    profile: Option<KernelProfileHandle>,
) -> ObsGuard {
    let ctx = ObsCtx { sink: sink.clone(), ring: sink.ring(worker), profile };
    let prev = CTX.with(|c| c.borrow_mut().replace(ctx));
    ObsGuard { prev, _not_send: PhantomData }
}

/// Attach (or replace) the kernel profile on the installed context —
/// the serving worker learns its module's profile only after the first
/// compile resolves, which happens after [`install`].
pub fn set_profile(profile: KernelProfileHandle) {
    CTX.with(|c| {
        if let Some(ctx) = c.borrow_mut().as_mut() {
            ctx.profile = Some(profile);
        }
    });
}

/// Whether any consumer (enabled sink or attached profile) would see a
/// recorded span from this thread right now.
pub fn active() -> bool {
    if !TRACE_COMPILED {
        return false;
    }
    CTX.with(|c| {
        c.borrow()
            .as_ref()
            .map(|ctx| ctx.sink.enabled() || ctx.profile.is_some())
            .unwrap_or(false)
    })
}

/// A started span. Holds no timestamp when recording is inactive, so
/// the disabled path never reads the clock.
#[must_use = "finish the span with obs::record / obs::launch"]
pub struct SpanTimer(Option<Instant>);

/// Start a span (reads the clock only when a recorder is active).
#[inline]
pub fn begin() -> SpanTimer {
    if active() {
        SpanTimer(Some(Instant::now()))
    } else {
        SpanTimer(None)
    }
}

fn dur_us(d: Duration) -> f64 {
    d.as_secs_f64() * 1e6
}

fn with_active_ctx(f: impl FnOnce(&ObsCtx)) {
    CTX.with(|c| {
        let b = c.borrow();
        if let Some(ctx) = b.as_ref() {
            f(ctx);
        }
    });
}

/// Finish a generic span started with [`begin`].
pub fn record(cat: SpanCat, name: &'static str, fp: u64, t: SpanTimer) {
    let Some(t0) = t.0 else { return };
    let elapsed = t0.elapsed();
    with_active_ctx(|ctx| {
        if !ctx.sink.enabled() {
            return;
        }
        ctx.ring.push(SpanEvent {
            cat,
            name,
            worker: ctx.ring.worker,
            start_us: dur_us(t0.saturating_duration_since(ctx.sink.epoch)),
            dur_us: dur_us(elapsed),
            fp,
            tier: None,
            fences: 0,
            barriers: 0,
        });
    });
}

/// Record a span from explicit endpoints (queue-wait spans start at the
/// request's enqueue time, long before the worker sees it).
pub fn record_between(cat: SpanCat, name: &'static str, fp: u64, start: Instant, end: Instant) {
    if !TRACE_COMPILED {
        return;
    }
    with_active_ctx(|ctx| {
        if !ctx.sink.enabled() {
            return;
        }
        ctx.ring.push(SpanEvent {
            cat,
            name,
            worker: ctx.ring.worker,
            start_us: dur_us(start.saturating_duration_since(ctx.sink.epoch)),
            dur_us: dur_us(end.saturating_duration_since(start)),
            fp,
            tier: None,
            fences: 0,
            barriers: 0,
        });
    });
}

/// Finish a generated-kernel launch span: feeds both the trace ring
/// (when the sink is enabled) and the kernel profile (when attached).
/// `delta` is the `LaunchLedger` movement of exactly this launch, so
/// fence/barrier counts and the tier tag come from measurement, not
/// from re-deriving the program shape.
pub fn launch(fp: u64, tier: StitchTier, modeled_us: f64, delta: &LaunchLedger, t: SpanTimer) {
    let Some(t0) = t.0 else { return };
    let elapsed = t0.elapsed();
    with_active_ctx(|ctx| {
        let wall_us = dur_us(elapsed);
        if let Some(profile) = &ctx.profile {
            profile.record_launch(fp, tier, modeled_us, wall_us, delta.fences, delta.barriers);
        }
        if ctx.sink.enabled() {
            ctx.ring.push(SpanEvent {
                cat: SpanCat::Launch,
                name: super::profile::tier_label(tier),
                worker: ctx.ring.worker,
                start_us: dur_us(t0.saturating_duration_since(ctx.sink.epoch)),
                dur_us: wall_us,
                fp,
                tier: Some(tier),
                fences: delta.fences.min(u32::MAX as u64) as u32,
                barriers: delta.barriers.min(u32::MAX as u64) as u32,
            });
        }
    });
}

/// Replay a cold compile's `PassTrace` as child spans of the compile
/// span that started at `t0`: pass wall times are laid out end-to-end
/// from the compile start, which is exactly how `PassManager` ran them.
pub fn record_passes(records: &[PassRecord], t0: Instant) {
    if !TRACE_COMPILED {
        return;
    }
    with_active_ctx(|ctx| {
        if !ctx.sink.enabled() {
            return;
        }
        let mut off = dur_us(t0.saturating_duration_since(ctx.sink.epoch));
        for r in records {
            ctx.ring.push(SpanEvent {
                cat: SpanCat::Pass,
                name: r.name,
                worker: ctx.ring.worker,
                start_us: off,
                dur_us: r.wall_us,
                fp: 0,
                tier: None,
                fences: 0,
                barriers: 0,
            });
            off += r.wall_us;
        }
    });
}

#[cfg(all(test, feature = "trace"))]
mod tests {
    use super::*;

    #[test]
    fn ring_overflow_drops_oldest_and_counts() {
        let sink = TraceSink::new(TraceConfig { enabled: true, capacity_per_worker: 8 });
        let _g = install(&sink, 0, None);
        for _ in 0..20 {
            record(SpanCat::Batch, "assemble", 0, begin());
        }
        let snap = sink.snapshot();
        assert_eq!(snap.events.len(), 8);
        assert_eq!(snap.dropped, 12);
        assert_eq!(sink.dropped_events(), 12);
    }

    #[test]
    fn disabled_sink_records_nothing_but_profile_still_collects() {
        let sink = TraceSink::new(TraceConfig { enabled: false, capacity_per_worker: 64 });
        let profile = KernelProfileHandle::new();
        let _g = install(&sink, 3, Some(profile.clone()));
        record(SpanCat::Reply, "reply", 0, begin());
        launch(7, StitchTier::Plain, 1.0, &LaunchLedger::default(), begin());
        assert_eq!(sink.snapshot().events.len(), 0);
        assert_eq!(profile.snapshot().total_launches(), 1);
    }

    #[test]
    fn uninstalled_thread_is_inert() {
        assert!(!active());
        record(SpanCat::Queue, "queue-wait", 0, begin());
        launch(1, StitchTier::Shm, 1.0, &LaunchLedger::default(), begin());
    }

    #[test]
    fn guard_restores_previous_context() {
        let outer = TraceSink::new(TraceConfig::default());
        let inner = TraceSink::new(TraceConfig::default());
        let _a = install(&outer, 0, None);
        {
            let _b = install(&inner, 1, None);
            record(SpanCat::Batch, "assemble", 0, begin());
        }
        record(SpanCat::Reply, "reply", 0, begin());
        assert_eq!(inner.snapshot().events.len(), 1);
        let outer_snap = outer.snapshot();
        assert_eq!(outer_snap.events.len(), 1);
        assert_eq!(outer_snap.events[0].cat, SpanCat::Reply);
    }
}
