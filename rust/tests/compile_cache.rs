//! Integration tests for compile-once serving: structural fingerprints,
//! the compilation cache (hit/miss/LRU), tuned-plan persistence, and
//! the cache on the live serving loop.

use fusion_stitching::coordinator::batcher::BatchPolicy;
use fusion_stitching::coordinator::cache::{CacheKey, CompileCache, CompileService};
use fusion_stitching::coordinator::pipeline::{FusionMode, PipelineConfig};
use fusion_stitching::coordinator::server::CompileOptions;
use fusion_stitching::coordinator::{compile_module_traced, ServerConfig, ServingCoordinator};
use fusion_stitching::gpusim::DeviceConfig;
use fusion_stitching::hlo::{fingerprint_module, GraphBuilder, Module, Shape};
use fusion_stitching::models;
use fusion_stitching::schedule::PerfLibrary;
use fusion_stitching::testutil::TempDir;
use std::sync::Arc;
use std::time::Duration;

#[test]
fn benchmark_fingerprints_are_stable_and_distinct() {
    let first: Vec<_> = models::all_benchmarks()
        .iter()
        .map(|(meta, m)| (meta.name, fingerprint_module(m)))
        .collect();
    let second: Vec<_> = models::all_benchmarks()
        .iter()
        .map(|(meta, m)| (meta.name, fingerprint_module(m)))
        .collect();
    assert_eq!(first, second, "rebuilding a benchmark must reproduce its fingerprint");
    for (i, (na, fa)) in first.iter().enumerate() {
        for (nb, fb) in &first[i + 1..] {
            assert_ne!(fa, fb, "{na} and {nb} must not collide");
        }
    }
}

#[test]
fn renumbered_graph_same_fingerprint_changed_graph_different() {
    // Same dataflow, different construction order → same hash.
    let mut b1 = GraphBuilder::new("e");
    let x = b1.param("x", Shape::f32(&[8, 32]));
    let y = b1.param("y", Shape::f32(&[8, 32]));
    let e = b1.exp(x);
    let t = b1.tanh(y);
    let s = b1.add(e, t);
    let m1 = Module::new("m1", b1.finish(s));

    let mut b2 = GraphBuilder::new("e");
    let x = b2.param("x", Shape::f32(&[8, 32]));
    let y = b2.param("y", Shape::f32(&[8, 32]));
    let t = b2.tanh(y); // swapped construction order
    let e = b2.exp(x);
    let s = b2.add(e, t);
    let m2 = Module::new("m2_other_name", b2.finish(s));

    assert_eq!(fingerprint_module(&m1), fingerprint_module(&m2));

    // Any shape change must change the hash.
    let mut b3 = GraphBuilder::new("e");
    let x = b3.param("x", Shape::f32(&[8, 64]));
    let y = b3.param("y", Shape::f32(&[8, 64]));
    let e = b3.exp(x);
    let t = b3.tanh(y);
    let s = b3.add(e, t);
    let m3 = Module::new("m3", b3.finish(s));
    assert_ne!(fingerprint_module(&m1), fingerprint_module(&m3));

    // Any opcode change must change the hash.
    let mut b4 = GraphBuilder::new("e");
    let x = b4.param("x", Shape::f32(&[8, 32]));
    let y = b4.param("y", Shape::f32(&[8, 32]));
    let e = b4.exp(x);
    let t = b4.sigmoid(y);
    let s = b4.add(e, t);
    let m4 = Module::new("m4", b4.finish(s));
    assert_ne!(fingerprint_module(&m1), fingerprint_module(&m4));
}

#[test]
fn cached_compile_skips_the_pipeline() {
    let mut svc = CompileService::new(PipelineConfig::default());
    let (_, module) = models::by_name("LR").unwrap();
    let (cold, hit0) = svc.compile(&module, FusionMode::FusionStitching).unwrap();
    assert!(!hit0);
    let tuned_after_cold = svc.perf_library().tuned_len();
    let (warm, hit1) = svc.compile(&module, FusionMode::FusionStitching).unwrap();
    assert!(hit1, "identical module must hit");
    assert!(Arc::ptr_eq(&cold, &warm), "hit returns the same artifact");
    // a hit runs no pass at all, so the tuned store cannot have grown
    assert_eq!(svc.perf_library().tuned_len(), tuned_after_cold);
    assert_eq!(svc.stats().hits, 1);
    assert_eq!(svc.stats().misses, 1);
}

#[test]
fn cache_key_separates_modes_and_devices() {
    let cfg = PipelineConfig::default();
    let (_, module) = models::by_name("LR").unwrap();
    let k1 = CacheKey::new(&module, FusionMode::FusionStitching, &cfg);
    let k2 = CacheKey::new(&module, FusionMode::XlaBaseline, &cfg);
    assert_ne!(k1, k2);
    let mut cfg2 = cfg.clone();
    cfg2.deep.device.name = "sim-volta".into();
    let k3 = CacheKey::new(&module, FusionMode::FusionStitching, &cfg2);
    assert_ne!(k1, k3);
}

#[test]
fn lru_eviction_bounds_residency() {
    let mut svc = CompileService::with_capacity(PipelineConfig::default(), 2);
    let (_, lr) = models::by_name("LR").unwrap();
    let (_, w2v) = models::by_name("W2V").unwrap();
    let (_, rnn) = models::by_name("RNN").unwrap();
    svc.compile(&lr, FusionMode::FusionStitching).unwrap();
    svc.compile(&w2v, FusionMode::FusionStitching).unwrap();
    svc.compile(&rnn, FusionMode::FusionStitching).unwrap(); // evicts LR
    assert_eq!(svc.cache().len(), 2);
    assert_eq!(svc.stats().evictions, 1);
    let (_, hit_rnn) = svc.compile(&rnn, FusionMode::FusionStitching).unwrap();
    assert!(hit_rnn);
    let (_, hit_lr) = svc.compile(&lr, FusionMode::FusionStitching).unwrap();
    assert!(!hit_lr, "evicted entry must recompile");
}

#[test]
fn direct_cache_api_counts_evictions() {
    let cfg = PipelineConfig::default();
    let mut lib = PerfLibrary::new(DeviceConfig::pascal());
    let mut cache = CompileCache::new(1);
    let (_, lr) = models::by_name("LR").unwrap();
    let (_, w2v) = models::by_name("W2V").unwrap();
    let (a, _) = compile_module_traced(&lr, FusionMode::FusionStitching, &mut lib, &cfg).unwrap();
    let (b, _) = compile_module_traced(&w2v, FusionMode::FusionStitching, &mut lib, &cfg).unwrap();
    let ka = CacheKey::new(&lr, FusionMode::FusionStitching, &cfg);
    let kb = CacheKey::new(&w2v, FusionMode::FusionStitching, &cfg);
    cache.insert(ka.clone(), Arc::new(a));
    cache.insert(kb.clone(), Arc::new(b));
    assert_eq!(cache.len(), 1);
    let stats = cache.stats();
    assert_eq!(stats.evictions, 1);
    assert!(cache.get(&ka).is_none());
    assert!(cache.get(&kb).is_some());
}

/// Identity-ish artifact the serving loop executes while the compile
/// service exercises the cache.
const DOUBLE_HLO: &str = r#"HloModule double, entry_computation_layout={(f32[4,3]{1,0})->(f32[4,3]{1,0})}

ENTRY main {
  p0 = f32[4,3]{1,0} parameter(0)
  sum = f32[4,3]{1,0} add(p0, p0)
  ROOT t = (f32[4,3]{1,0}) tuple(sum)
}
"#;

#[test]
fn serving_loop_reports_cache_hits_for_repeated_nmt_requests() {
    let dir = TempDir::new("cc-serve");
    std::fs::write(dir.path().join("double.hlo.txt"), DOUBLE_HLO).unwrap();

    let (meta, nmt) = models::by_name("NMT").unwrap();
    let mut pipeline = PipelineConfig::default();
    pipeline.deep.fuse_batch_dot = meta.fuse_batch_dot;

    let cfg = ServerConfig {
        artifact: "double".into(),
        batch: 4,
        in_elems_per_request: 3,
        out_elems_per_request: 3,
        input_dims: vec![4, 3],
        policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        compile: Some(CompileOptions {
            module: nmt,
            mode: FusionMode::FusionStitching,
            pipeline,
            use_stitched_backend: false,
            specialize: None,
        }),
        buckets: None,
        trace: None,
        deadline: None,
        faults: None,
    };
    let srv = ServingCoordinator::start(dir.path(), cfg).unwrap();
    for i in 0..4 {
        let (out, _) = srv.infer(vec![1.0 + i as f32, 0.0, -1.0]).unwrap();
        assert_eq!(out, vec![2.0 + 2.0 * i as f32, 0.0, -2.0]);
    }
    let stats = srv.shutdown().unwrap();
    assert_eq!(stats.cache_misses, 1, "NMT compiles exactly once");
    assert!(stats.cache_hits >= 3, "repeated requests must hit: {stats:?}");
    assert!(stats.cache_hit_rate() > 0.0);
    // warm compile latency collapses vs the cold compile
    assert!(stats.compile_us.count() >= 4);
    let cold = stats.compile_us.first_us();
    let warm_best = stats.compile_us.min_us();
    assert!(
        warm_best < cold,
        "cache hit ({warm_best} us) should be cheaper than cold compile ({cold} us)"
    );
}

#[test]
fn shared_service_amortizes_across_serving_loops() {
    let dir = TempDir::new("cc-share");
    std::fs::write(dir.path().join("double.hlo.txt"), DOUBLE_HLO).unwrap();
    let (_, lr) = models::by_name("LR").unwrap();
    let service = Arc::new(std::sync::Mutex::new(CompileService::new(PipelineConfig::default())));
    let cfg = ServerConfig {
        artifact: "double".into(),
        batch: 4,
        in_elems_per_request: 3,
        out_elems_per_request: 3,
        input_dims: vec![4, 3],
        policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        compile: Some(CompileOptions {
            module: lr,
            mode: FusionMode::FusionStitching,
            pipeline: PipelineConfig::default(),
            use_stitched_backend: false,
            specialize: None,
        }),
        buckets: None,
        trace: None,
        deadline: None,
        faults: None,
    };

    let srv1 = ServingCoordinator::start_with_service(dir.path(), cfg.clone(), service.clone())
        .unwrap();
    srv1.infer(vec![0.0; 3]).unwrap();
    let s1 = srv1.shutdown().unwrap();
    assert_eq!(s1.cache_misses, 1);

    // A second loop over the same service: its first batch already hits.
    let srv2 =
        ServingCoordinator::start_with_service(dir.path(), cfg, service.clone()).unwrap();
    srv2.infer(vec![0.0; 3]).unwrap();
    let s2 = srv2.shutdown().unwrap();
    assert_eq!(s2.cache_misses, 0, "warm service: no cold compile in loop 2");
    assert!(s2.cache_hits >= 1);
    assert!(service.lock().unwrap().stats().hits >= 1);
}
