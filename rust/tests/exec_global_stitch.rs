//! Global-memory stitching tier acceptance suite.
//!
//! The tentpole claim: when an intermediate's per-block chunk overflows
//! the shared-memory budget, materializing it in a global-memory spill
//! region behind a grid fence (instead of splitting the group) must be
//! **bit-identical** to the split plan — boxed reference path and
//! block-parallel fast path at every thread count — while the launch
//! ledger shows no more, and on the overflow corpus strictly fewer,
//! executed kernel launches.

use fusion_stitching::coordinator::pipeline::{compile_module, FusionMode, PipelineConfig};
use fusion_stitching::corpus::generator::{generate_models, generate_overflow_models, CorpusConfig};
use fusion_stitching::exec::{ExecArena, StitchedExecutable};
use fusion_stitching::gpusim::DeviceConfig;
use fusion_stitching::hlo::Module;
use fusion_stitching::schedule::PerfLibrary;

/// Same stream as the other differential harnesses: small widths so
/// every graph executes in test time.
fn mini_corpus() -> Vec<Module> {
    let cfg = CorpusConfig {
        seed: 946,
        models: 16,
        ops_per_model: (8, 24),
        max_width_log2: 6,
    };
    generate_models(&cfg)
        .into_iter()
        .map(|c| {
            let name = c.name.clone();
            Module::new(name, c)
        })
        .collect()
}

/// The large-intermediate tail: every model's interior reduce overflows
/// the default shared-memory budget under every legal schedule.
fn overflow_modules() -> Vec<Module> {
    generate_overflow_models()
        .into_iter()
        .map(|c| {
            let name = c.name.clone();
            Module::new(name, c)
        })
        .collect()
}

fn fill(n: usize, seed: u64) -> Vec<f32> {
    (0..n)
        .map(|i| {
            let h = (i as u64).wrapping_mul(2654435761).wrapping_add(seed.wrapping_mul(97));
            ((h % 1000) as f32) / 1000.0 - 0.5
        })
        .collect()
}

fn inputs_for(module: &Module, seed: u64) -> Vec<Vec<f32>> {
    module
        .entry
        .parameters()
        .into_iter()
        .enumerate()
        .map(|(k, id)| {
            let elems = module.entry.get(id).shape.num_elements() as usize;
            fill(elems, seed + k as u64)
        })
        .collect()
}

fn lower_gs(module: &Module, fuse_batch_dot: bool, global_stitch: bool) -> StitchedExecutable {
    let mut lib = PerfLibrary::new(DeviceConfig::pascal());
    let mut cfg = PipelineConfig::default();
    cfg.deep.fuse_batch_dot = fuse_batch_dot;
    cfg.deep.global_stitch = global_stitch;
    let compiled = compile_module(module, FusionMode::FusionStitching, &mut lib, &cfg)
        .unwrap_or_else(|e| panic!("{}: compile failed: {e:#}", module.name));
    match compiled.executable {
        Some(exe) => (*exe).clone(),
        None => panic!("{}: did not lower: {:?}", module.name, compiled.exec_error),
    }
}

fn assert_bit_identical(name: &str, a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{name}: {what}: output size");
    for (k, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            x.to_bits() == y.to_bits(),
            "{name}: {what}: element {k} differs: {x} vs {y}"
        );
    }
}

#[test]
fn global_stitched_plans_are_bit_identical_to_split_plans() {
    // Corpus + light benchmarks + the overflow tail, each compiled with
    // the global tier on and off: outputs must agree bit-for-bit (the
    // VM computes each element in a fixed order regardless of
    // grouping), and the stitched plan never launches more kernels.
    let mut suite: Vec<(Module, bool)> =
        mini_corpus().into_iter().map(|m| (m, false)).collect();
    for (meta, module) in [
        fusion_stitching::models::by_name("LR").unwrap(),
        fusion_stitching::models::by_name("W2V").unwrap(),
        fusion_stitching::models::by_name("Speech").unwrap(),
    ] {
        suite.push((module, meta.fuse_batch_dot));
    }
    for m in overflow_modules() {
        suite.push((m, false));
    }

    for (i, (module, fuse_bd)) in suite.iter().enumerate() {
        let inputs = inputs_for(module, 6000 + i as u64);
        let stitched = lower_gs(module, *fuse_bd, true);
        let split = lower_gs(module, *fuse_bd, false);
        let (s_out, s_ledger) = stitched
            .run_boxed(&inputs)
            .unwrap_or_else(|e| panic!("{}: stitched run failed: {e:#}", module.name));
        let (p_out, p_ledger) = split
            .run_boxed(&inputs)
            .unwrap_or_else(|e| panic!("{}: split run failed: {e:#}", module.name));
        assert_bit_identical(&module.name, &s_out, &p_out, "stitched vs split");
        assert!(
            s_ledger.total_launches() <= p_ledger.total_launches(),
            "{}: global stitching launched {} vs split {}",
            module.name,
            s_ledger.total_launches(),
            p_ledger.total_launches()
        );
        assert_eq!(
            s_ledger.library, p_ledger.library,
            "{}: the global tier must not touch library calls",
            module.name
        );
    }
}

#[test]
fn overflow_models_take_the_global_tier_and_strictly_save_launches() {
    // The acceptance bar: on the overflow corpus the global tier
    // actually fires (fenced launches attributed to `tier_global`) and
    // the stitched plan executes *strictly fewer* launches than the
    // split plan forced by `global_stitch = false`.
    for (i, module) in overflow_modules().iter().enumerate() {
        let inputs = inputs_for(module, 7000 + i as u64);
        let stitched = lower_gs(module, false, true);
        let split = lower_gs(module, false, false);
        let (s_out, s_ledger) = stitched.run_boxed(&inputs).unwrap();
        let (p_out, p_ledger) = split.run_boxed(&inputs).unwrap();
        assert_bit_identical(&module.name, &s_out, &p_out, "stitched vs split");
        assert!(
            s_ledger.tier_global > 0,
            "{}: expected a global-tier launch, ledger: {s_ledger}",
            module.name
        );
        assert!(
            s_ledger.fences > 0,
            "{}: a global-tier launch must cross a grid fence",
            module.name
        );
        assert_eq!(
            p_ledger.tier_global, 0,
            "{}: the split plan must not use the global tier",
            module.name
        );
        assert_eq!(p_ledger.fences, 0, "{}: split plans have no fences", module.name);
        assert!(
            s_ledger.total_launches() < p_ledger.total_launches(),
            "{}: global stitching must strictly reduce launches: {} vs {}",
            module.name,
            s_ledger.total_launches(),
            p_ledger.total_launches()
        );
    }
}

#[test]
fn fast_path_matches_boxed_on_global_stitched_plans_at_every_thread_count() {
    // The fence model in the block-parallel path: one fan-out per
    // fence-delimited phase, the join *is* the fence. Outputs and
    // ledgers must be bit-identical to the boxed reference at 1, 2 and
    // 4 VM threads.
    for (i, module) in overflow_modules().iter().enumerate() {
        let inputs = inputs_for(module, 8000 + i as u64);
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let exe = lower_gs(module, false, true);
        let (boxed_out, boxed_ledger) = exe.run_boxed(&inputs).unwrap();
        assert!(boxed_ledger.fences > 0, "{}: suite must exercise fences", module.name);
        for threads in [1usize, 2, 4] {
            let mut arena = ExecArena::with_threads(threads);
            let mut fast_out = Vec::new();
            let fast_ledger = exe
                .run_into(&refs, &mut arena, &mut fast_out)
                .unwrap_or_else(|e| {
                    panic!("{} @ {threads} threads: fast run failed: {e:#}", module.name)
                });
            assert_eq!(
                fast_ledger, boxed_ledger,
                "{} @ {threads} threads: launch ledger changed",
                module.name
            );
            assert_bit_identical(
                &module.name,
                &fast_out,
                &boxed_out,
                &format!("fast @ {threads} threads vs boxed"),
            );
        }
    }
}

#[test]
fn all_benchmark_models_compile_under_both_settings() {
    // Running NMT/RNN/BiRNN in debug is impractical, but every Table 2
    // model must *compile* with the global tier on and off, and the
    // stitched plan's static launch count may never exceed the split
    // plan's.
    for (meta, module) in fusion_stitching::models::all_benchmarks() {
        let stitched = lower_gs(&module, meta.fuse_batch_dot, true);
        let split = lower_gs(&module, meta.fuse_batch_dot, false);
        let s = stitched.generated_launches() + stitched.library_launches();
        let p = split.generated_launches() + split.library_launches();
        assert!(
            s <= p,
            "{}: global stitching plans {} launches vs split {}",
            meta.name,
            s,
            p
        );
    }
}
