//! Integration tests: the full pipeline (fusion → scheduling → shm →
//! codegen → simulation) over every Table 2 benchmark, under both
//! fusion modes, checking the paper's cross-cutting invariants.

use fusion_stitching::codegen::emitter::emit_group;
use fusion_stitching::coordinator::pipeline::{
    compile_module, evaluate, geomean, FusionMode, PipelineConfig,
};
use fusion_stitching::fusion::GroupKind;
use fusion_stitching::gpusim::DeviceConfig;
use fusion_stitching::models;
use fusion_stitching::schedule::{tune, PerfLibrary, TuningConfig};

fn setup() -> (PerfLibrary, PipelineConfig) {
    (PerfLibrary::new(DeviceConfig::pascal()), PipelineConfig::default())
}

#[test]
fn all_benchmarks_compile_under_both_modes() {
    let (mut lib, cfg) = setup();
    for (meta, module) in models::all_benchmarks() {
        let mut cfg = cfg.clone();
        cfg.deep.fuse_batch_dot = meta.fuse_batch_dot;
        for mode in [FusionMode::XlaBaseline, FusionMode::FusionStitching] {
            let compiled = compile_module(&module, mode, &mut lib, &cfg)
                .unwrap_or_else(|e| panic!("{} {mode:?}: {e:#}", meta.name));
            compiled.plan.validate(&module.entry).unwrap();
            assert!(compiled.timing.total_us() > 0.0);
        }
    }
}

#[test]
fn fusion_never_increases_kernel_count() {
    let (mut lib, cfg) = setup();
    for (meta, module) in models::all_benchmarks() {
        let mut cfg = cfg.clone();
        cfg.deep.fuse_batch_dot = meta.fuse_batch_dot;
        let base = compile_module(&module, FusionMode::XlaBaseline, &mut lib, &cfg).unwrap();
        let fs =
            compile_module(&module, FusionMode::FusionStitching, &mut lib, &cfg).unwrap();
        let b = base.plan.generated_kernel_count(&module.entry);
        let f = fs.plan.generated_kernel_count(&module.entry);
        assert!(f <= b, "{}: FS {f} > baseline {b}", meta.name);
        // and the unfused graph is an upper bound for both
        assert!(b <= module.entry.unfused_kernel_count());
    }
}

#[test]
fn library_kernels_identical_across_modes() {
    // Fusion never touches library calls (§3.2).
    let (mut lib, cfg) = setup();
    for (meta, module) in models::all_benchmarks() {
        let base = compile_module(&module, FusionMode::XlaBaseline, &mut lib, &cfg).unwrap();
        let fs =
            compile_module(&module, FusionMode::FusionStitching, &mut lib, &cfg).unwrap();
        assert_eq!(
            base.plan.library_call_count(),
            fs.plan.library_call_count(),
            "{}",
            meta.name
        );
    }
}

#[test]
fn shared_memory_budget_respected_everywhere() {
    let (mut lib, cfg) = setup();
    let limit = cfg.deep.device.shared_mem_kernel_limit;
    for (meta, module) in models::all_benchmarks() {
        let fs =
            compile_module(&module, FusionMode::FusionStitching, &mut lib, &cfg).unwrap();
        for k in &fs.kernels {
            assert!(
                k.shm.total_bytes <= limit,
                "{}: kernel {} uses {} B > {limit} B",
                meta.name,
                k.name,
                k.shm.total_bytes
            );
        }
    }
}

#[test]
fn emitted_ir_is_well_formed() {
    // Every shared write is followed by a barrier; every root writes
    // global memory; launch dims appear in the header.
    let (mut lib, cfg) = setup();
    for (meta, module) in models::all_benchmarks() {
        let fs =
            compile_module(&module, FusionMode::FusionStitching, &mut lib, &cfg).unwrap();
        for k in &fs.kernels {
            let text = k.ir_text();
            assert_eq!(
                text.matches("EmitWriteSharedArray").count(),
                text.matches("__syncthreads").count(),
                "{}: barrier/write mismatch in {}",
                meta.name,
                k.name
            );
            assert!(
                text.contains("EmitWriteOutputArray"),
                "{}: kernel {} has no global output",
                meta.name,
                k.name
            );
            assert!(text.contains(&format!("<<<{}, {}>>>", k.blocks, k.threads)));
        }
    }
}

#[test]
fn stitched_groups_have_interior_heavy_ops() {
    // GroupKind::Stitched ⟺ a reduce/batch-dot is interior (non-root).
    let (mut lib, cfg) = setup();
    for (meta, module) in models::all_benchmarks() {
        let fs =
            compile_module(&module, FusionMode::FusionStitching, &mut lib, &cfg).unwrap();
        for g in &fs.plan.groups {
            if g.kind != GroupKind::Stitched {
                continue;
            }
            let interior_heavy = g.members.iter().any(|&id| {
                let i = module.entry.get(id);
                let heavy = i.opcode.is_reduce()
                    || i.opcode == fusion_stitching::hlo::Opcode::BatchDot;
                heavy && module.entry.users(id).iter().any(|u| g.members.contains(u))
            });
            assert!(interior_heavy, "{}: stitched group without interior heavy op", meta.name);
        }
    }
}

#[test]
fn paper_headline_shapes_hold() {
    let (mut lib, cfg) = setup();
    let mut ratios = Vec::new();
    let mut reports = Vec::new();
    for (meta, module) in models::all_benchmarks() {
        let r = evaluate(&meta, &module, &mut lib, &cfg).unwrap();
        ratios.push(r.fusion_ratio);
        reports.push(r);
    }
    // headline: large kernel-launch reduction (paper: geomean 0.45)
    let g = geomean(ratios.iter().copied());
    assert!(g < 0.75, "geomean fusion ratio {g}");
    // W2V is the least fusable (paper: 0.82, the highest ratio)
    let w2v = reports.iter().find(|r| r.name == "W2V").unwrap();
    assert!(
        reports.iter().all(|r| r.fusion_ratio <= w2v.fusion_ratio + 1e-9),
        "W2V should have the highest fusion ratio"
    );
    // all speedups ≥ 1, prediction tracks measurement (Fig. 8)
    for r in &reports {
        assert!(r.fusion_speedup >= 1.0, "{}", r.name);
        assert!(r.measured_e2e >= 1.0, "{}", r.name);
        assert!((r.predicted_e2e - r.measured_e2e).abs() / r.measured_e2e < 0.40, "{}", r.name);
    }
    // NMT exhibits buffer reuse (Table 3's shared ratio)
    let nmt = reports.iter().find(|r| r.name == "NMT").unwrap();
    assert!(nmt.shm_shared_ratio > 0.0);
}

#[test]
fn perf_library_amortizes_across_compilations() {
    let (mut lib, cfg) = setup();
    for (_, module) in models::all_benchmarks() {
        let _ = compile_module(&module, FusionMode::FusionStitching, &mut lib, &cfg).unwrap();
    }
    let after_first = lib.len();
    for (_, module) in models::all_benchmarks() {
        let _ = compile_module(&module, FusionMode::FusionStitching, &mut lib, &cfg).unwrap();
    }
    assert_eq!(lib.len(), after_first, "second pass must be all hits");
    assert!(lib.hit_rate() > 0.5);
}

#[test]
fn group_emission_is_deterministic() {
    let (mut lib, cfg) = setup();
    let (_, module) = models::by_name("NMT").unwrap();
    let a = compile_module(&module, FusionMode::FusionStitching, &mut lib, &cfg).unwrap();
    let b = compile_module(&module, FusionMode::FusionStitching, &mut lib, &cfg).unwrap();
    let ta: Vec<String> = a.kernels.iter().map(|k| k.ir_text()).collect();
    let tb: Vec<String> = b.kernels.iter().map(|k| k.ir_text()).collect();
    assert_eq!(ta, tb, "compilation must be deterministic");
}

#[test]
fn manual_group_tune_and_emit_roundtrip() {
    // Drive tune + emit directly on a benchmark subgraph (API-level use).
    let (_, module) = models::by_name("LR").unwrap();
    let comp = &module.entry;
    let mut lib = PerfLibrary::new(DeviceConfig::pascal());
    // largest FS group from the plan
    let fs = compile_module(&module, FusionMode::FusionStitching, &mut lib, &PipelineConfig::default()).unwrap();
    let g = fs
        .plan
        .groups
        .iter()
        .filter(|g| g.kind != GroupKind::Library)
        .max_by_key(|g| g.members.len())
        .unwrap();
    let tuned = tune(comp, &g.members, &g.roots, &mut lib, &TuningConfig::default()).unwrap();
    let plan = emit_group(comp, &g.members, &g.roots, &tuned, &DeviceConfig::pascal(), "manual")
        .unwrap();
    assert_eq!(plan.blocks, tuned.blocks);
    assert!(!plan.ops.is_empty());
}
