//! Differential harness: stitched execution vs the op-by-op
//! interpreter over the synthetic corpus.
//!
//! Every corpus graph (all of whose opcodes the interpreter covers —
//! that is the point of the interpreter-widening satellite) is executed
//! three ways:
//!
//! 1. op-by-op on the HLO-text interpreter (per-op launch baseline),
//! 2. on the stitched VM under the XLA-baseline fusion plan,
//! 3. on the stitched VM under the deep-fusion (FusionStitching) plan,
//!
//! and the results must agree to 1e-5 max-abs-diff while the deep
//! fusion `LaunchLedger` shows strictly fewer launches than the per-op
//! baseline in aggregate — the repo's first *executed* (not estimated)
//! version of the paper's Fig. 7 claim.

use fusion_stitching::coordinator::pipeline::{compile_module, FusionMode, PipelineConfig};
use fusion_stitching::corpus::generator::{generate_models, CorpusConfig};
use fusion_stitching::exec::StitchedExecutable;
use fusion_stitching::gpusim::DeviceConfig;
use fusion_stitching::hlo::printer::xla_text;
use fusion_stitching::hlo::Module;
use fusion_stitching::runtime::interp::HloProgram;
use fusion_stitching::schedule::PerfLibrary;

/// Small widths so every graph executes in test time; same generator
/// stream as the Figure 1 corpus otherwise.
fn mini_corpus() -> Vec<Module> {
    let cfg = CorpusConfig {
        seed: 946,
        models: 16,
        ops_per_model: (8, 24),
        max_width_log2: 6,
    };
    generate_models(&cfg)
        .into_iter()
        .map(|c| {
            let name = c.name.clone();
            Module::new(name, c)
        })
        .collect()
}

fn fill(n: usize, seed: u64) -> Vec<f32> {
    (0..n)
        .map(|i| {
            let h = (i as u64).wrapping_mul(2654435761).wrapping_add(seed.wrapping_mul(97));
            ((h % 1000) as f32) / 1000.0 - 0.5
        })
        .collect()
}

fn inputs_for(module: &Module, seed: u64) -> Vec<Vec<f32>> {
    module
        .entry
        .parameters()
        .into_iter()
        .enumerate()
        .map(|(k, id)| {
            let elems = module.entry.get(id).shape.num_elements() as usize;
            fill(elems, seed + k as u64)
        })
        .collect()
}

fn lower(module: &Module, mode: FusionMode) -> StitchedExecutable {
    lower_cfg(module, mode, true)
}

fn lower_cfg(module: &Module, mode: FusionMode, cost_fusion: bool) -> StitchedExecutable {
    let mut lib = PerfLibrary::new(DeviceConfig::pascal());
    let mut cfg = PipelineConfig::default();
    cfg.deep.cost_fusion = cost_fusion;
    let compiled = compile_module(module, mode, &mut lib, &cfg)
        .unwrap_or_else(|e| panic!("{}: compile failed: {e:#}", module.name));
    match compiled.executable {
        Some(exe) => (*exe).clone(),
        None => panic!("{}: did not lower: {:?}", module.name, compiled.exec_error),
    }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "output length mismatch");
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max)
}

#[test]
fn stitched_execution_matches_interpreter_on_corpus() {
    let modules = mini_corpus();
    assert!(modules.len() >= 12, "corpus too small to be meaningful");

    let mut per_op_total = 0u64;
    let mut fs_total = 0u64;
    let mut baseline_total = 0u64;
    let mut strictly_fewer = 0usize;

    for (i, module) in modules.iter().enumerate() {
        // 1. per-op interpreter baseline (covers every corpus opcode)
        let text = xla_text(module);
        let prog = HloProgram::parse(&text)
            .unwrap_or_else(|e| panic!("{}: interpreter must cover the corpus: {e:#}\n{text}", module.name));
        let inputs = inputs_for(module, 1000 + i as u64);
        let interp_out = prog
            .execute(&inputs)
            .unwrap_or_else(|e| panic!("{}: interpreter execution failed: {e:#}", module.name));
        let per_op = prog.kernel_launches();

        // 2. stitched VM, XLA-baseline plan
        let base = lower(module, FusionMode::XlaBaseline);
        let (base_out, base_ledger) = base
            .run(&inputs)
            .unwrap_or_else(|e| panic!("{}: baseline stitched run failed: {e:#}", module.name));

        // 3. stitched VM, deep-fusion plan
        let fs = lower(module, FusionMode::FusionStitching);
        let (fs_out, fs_ledger) = fs
            .run(&inputs)
            .unwrap_or_else(|e| panic!("{}: deep-fusion stitched run failed: {e:#}", module.name));

        let d1 = max_abs_diff(&interp_out[0], &base_out);
        let d2 = max_abs_diff(&interp_out[0], &fs_out);
        assert!(d1 < 1e-5, "{}: baseline diverged from interpreter by {d1}", module.name);
        assert!(d2 < 1e-5, "{}: deep fusion diverged from interpreter by {d2}", module.name);

        // launch accounting: fused plans never launch more than per-op
        assert!(
            fs_ledger.total_launches() <= per_op,
            "{}: deep fusion launched {} vs per-op {}",
            module.name,
            fs_ledger.total_launches(),
            per_op
        );
        assert!(
            fs_ledger.total_launches() <= base_ledger.total_launches(),
            "{}: deep fusion launched more than the XLA baseline",
            module.name
        );
        if fs_ledger.total_launches() < per_op {
            strictly_fewer += 1;
        }
        per_op_total += per_op;
        fs_total += fs_ledger.total_launches();
        baseline_total += base_ledger.total_launches();
    }

    // The acceptance bar: deep fusion strictly reduces launches vs the
    // per-op baseline — in aggregate and on the clear majority of graphs.
    assert!(
        fs_total < per_op_total,
        "deep fusion must strictly reduce launches: {fs_total} vs {per_op_total}"
    );
    assert!(
        strictly_fewer * 2 > modules.len(),
        "launch reduction should hold on most graphs ({strictly_fewer}/{})",
        modules.len()
    );
    assert!(
        fs_total <= baseline_total,
        "deep fusion must not exceed the XLA baseline: {fs_total} vs {baseline_total}"
    );
}

#[test]
fn cost_guided_plans_stay_bit_identical_and_never_launch_more_than_greedy() {
    // The fusion-explore acceptance bar: whatever merges/splits the
    // cost-guided pass performs, execution must stay *bit-identical* to
    // the greedy plan (the VM computes each element in a fixed order
    // regardless of grouping) and must never pay more kernel launches.
    let mut corpus_modules = mini_corpus();
    for name in ["LR", "W2V", "Speech"] {
        let (_, module) = fusion_stitching::models::by_name(name).unwrap();
        corpus_modules.push(module);
    }
    for (i, module) in corpus_modules.iter().enumerate() {
        let inputs = inputs_for(module, 4000 + i as u64);
        let greedy = lower_cfg(module, FusionMode::FusionStitching, false);
        let explored = lower_cfg(module, FusionMode::FusionStitching, true);
        let (g_out, g_ledger) = greedy
            .run(&inputs)
            .unwrap_or_else(|e| panic!("{}: greedy run failed: {e:#}", module.name));
        let (x_out, x_ledger) = explored
            .run(&inputs)
            .unwrap_or_else(|e| panic!("{}: explored run failed: {e:#}", module.name));
        assert_eq!(g_out.len(), x_out.len(), "{}: output size changed", module.name);
        for (k, (a, b)) in g_out.iter().zip(&x_out).enumerate() {
            assert!(
                a.to_bits() == b.to_bits(),
                "{}: element {k} differs: {a} vs {b}",
                module.name
            );
        }
        assert!(
            x_ledger.total_launches() <= g_ledger.total_launches(),
            "{}: cost-guided launched {} vs greedy {}",
            module.name,
            x_ledger.total_launches(),
            g_ledger.total_launches()
        );
        assert_eq!(
            x_ledger.library, g_ledger.library,
            "{}: exploration must not touch library calls",
            module.name
        );
    }
}

#[test]
fn stitched_conv_matches_interpreter() {
    // The mini corpus caps widths below the conv threshold, so cover
    // `convolution` with a dedicated graph.
    use fusion_stitching::hlo::{GraphBuilder, Shape};
    let mut b = GraphBuilder::new("convnet");
    let x = b.param("x", Shape::f32(&[2, 8, 8, 3]));
    let k = b.param("k", Shape::f32(&[3, 3, 3, 4]));
    let c = b.conv2d(x, k);
    let t = b.tanh(c);
    let module = Module::new("convnet", b.finish(t));

    let inputs = inputs_for(&module, 7);
    let prog = HloProgram::parse(&xla_text(&module)).unwrap();
    let interp_out = prog.execute(&inputs).unwrap();

    let fs = lower(&module, FusionMode::FusionStitching);
    let (fs_out, ledger) = fs.run(&inputs).unwrap();
    assert!(max_abs_diff(&interp_out[0], &fs_out) < 1e-5);
    assert_eq!(ledger.library, 1, "conv must launch as a library call");
}

#[test]
fn all_benchmark_models_lower_to_executables() {
    // The Table 2 models cover the full fusable-op surface (transpose,
    // concat, slice, batch-dot, library dot/conv, constants): lowering
    // must succeed for every one under both fusion modes, so the
    // launch-reduction bench can execute them all.
    use fusion_stitching::models;
    for (meta, module) in models::all_benchmarks() {
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let mut cfg = PipelineConfig::default();
        cfg.deep.fuse_batch_dot = meta.fuse_batch_dot;
        for mode in [FusionMode::XlaBaseline, FusionMode::FusionStitching] {
            let compiled = compile_module(&module, mode, &mut lib, &cfg)
                .unwrap_or_else(|e| panic!("{} {mode:?}: {e:#}", meta.name));
            assert!(
                compiled.executable.is_some(),
                "{} {mode:?} did not lower: {:?}",
                meta.name,
                compiled.exec_error
            );
        }
    }
}

#[test]
fn cached_artifacts_carry_the_executable() {
    // Cache hits must skip lowering too: the Arc'd artifact already
    // holds the executable.
    use fusion_stitching::coordinator::cache::CompileService;
    use fusion_stitching::hlo::{GraphBuilder, Shape};

    let mut b = GraphBuilder::new("entry");
    let x = b.param("x", Shape::f32(&[16, 8]));
    let e = b.exp(x);
    let t = b.tanh(e);
    let module = Module::new("cached", b.finish(t));

    let mut svc = CompileService::new(PipelineConfig::default());
    let (cold, hit_a) = svc.compile(&module, FusionMode::FusionStitching).unwrap();
    let (warm, hit_b) = svc.compile(&module, FusionMode::FusionStitching).unwrap();
    assert!(!hit_a && hit_b);
    let cold_exe = cold.executable.as_ref().expect("must lower");
    let warm_exe = warm.executable.as_ref().expect("cached artifact keeps the executable");
    assert!(std::sync::Arc::ptr_eq(cold_exe, warm_exe), "hit must reuse the lowered artifact");
    let (out, ledger) = warm_exe.run(&[fill(128, 5)]).unwrap();
    assert_eq!(out.len(), 128);
    assert_eq!(ledger.generated, 1);
}
