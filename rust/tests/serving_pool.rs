//! Integration tests for the sharded multi-worker serving engine:
//! concurrent compile-once serving (single-flight cold compiles across
//! live workers), aggregate stats, and the oversized-batch/-row
//! regressions end to end.

use fusion_stitching::coordinator::batcher::BatchPolicy;
use fusion_stitching::coordinator::server::CompileOptions;
use fusion_stitching::coordinator::{
    FusionMode, PipelineConfig, PoolConfig, ServerConfig, ServingPool, SharedCompileService,
};
use fusion_stitching::models;
use fusion_stitching::testutil::TempDir;
use std::sync::Arc;
use std::time::Duration;

/// Identity-ish artifact: doubles a [4, 3] batch.
const DOUBLE_HLO: &str = r#"HloModule double, entry_computation_layout={(f32[4,3]{1,0})->(f32[4,3]{1,0})}

ENTRY main {
  p0 = f32[4,3]{1,0} parameter(0)
  sum = f32[4,3]{1,0} add(p0, p0)
  ROOT t = (f32[4,3]{1,0}) tuple(sum)
}
"#;

fn base_config() -> ServerConfig {
    ServerConfig {
        artifact: "double".into(),
        batch: 4,
        in_elems_per_request: 3,
        out_elems_per_request: 3,
        input_dims: vec![4, 3],
        policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) },
        compile: None,
        buckets: None,
        trace: None,
        deadline: None,
        faults: None,
    }
}

fn compile_config() -> ServerConfig {
    let (meta, nmt) = models::by_name("NMT").unwrap();
    let mut pipeline = PipelineConfig::default();
    pipeline.deep.fuse_batch_dot = meta.fuse_batch_dot;
    let mut cfg = base_config();
    cfg.compile = Some(CompileOptions {
        module: nmt,
        mode: FusionMode::FusionStitching,
        pipeline,
        use_stitched_backend: false,
        specialize: None,
    });
    cfg
}

/// The acceptance gate for the concurrent cache: multiple live workers
/// fetch the same fingerprint simultaneously on their very first batch,
/// and exactly one cold compile runs — the rest wait on the in-flight
/// slot and hit.
#[test]
fn concurrent_workers_share_one_cold_compile() {
    let dir = TempDir::new("pool-sf");
    std::fs::write(dir.path().join("double.hlo.txt"), DOUBLE_HLO).unwrap();
    let pool = ServingPool::start(
        dir.path(),
        compile_config(),
        PoolConfig { workers: 4, queue_depth: 16, ..PoolConfig::default() },
    )
    .unwrap();

    // Fire one request per shard *concurrently*: every worker's first
    // batch races into the shared service for the same NMT fingerprint.
    let mut keys = Vec::new();
    for key in 0..4096u64 {
        if keys.iter().all(|&k| pool.route(k) != pool.route(key)) {
            keys.push(key);
            if keys.len() == 4 {
                break;
            }
        }
    }
    let pending: Vec<_> = keys
        .iter()
        .map(|&k| pool.infer_keyed_async(k, vec![1.0, 2.0, 3.0]).unwrap())
        .collect();
    for rx in pending {
        assert_eq!(rx.recv().unwrap().unwrap(), vec![2.0, 4.0, 6.0]);
    }

    let service = pool.compile_service().unwrap().clone();
    let stats = pool.shutdown().unwrap();
    assert_eq!(
        service.cold_compiles(),
        1,
        "N workers racing on one fingerprint must run exactly one cold pipeline"
    );
    assert_eq!(stats.aggregate.cache_misses, 1, "one worker observed the miss");
    assert!(
        stats.aggregate.cache_hits >= stats.aggregate.batches - 1,
        "everyone else hit: {:?}",
        stats.aggregate
    );
    assert_eq!(stats.cold_compiles, Some(1));
}

/// A pre-warmed shared service serves every pool worker's first batch
/// from the cache — no cold compile at all on the serving path.
#[test]
fn prewarmed_shared_service_skips_cold_compiles() {
    let dir = TempDir::new("pool-warm");
    std::fs::write(dir.path().join("double.hlo.txt"), DOUBLE_HLO).unwrap();
    let cfg = compile_config();
    let opts = cfg.compile.as_ref().unwrap();
    let service = Arc::new(SharedCompileService::new(opts.pipeline.clone()));
    // warmup job: pay the compile before serving starts
    service.compile(&opts.module, opts.mode).unwrap();
    assert_eq!(service.cold_compiles(), 1);

    let pool = ServingPool::start_with_service(
        dir.path(),
        cfg,
        PoolConfig { workers: 2, queue_depth: 16, ..PoolConfig::default() },
        service.clone(),
    )
    .unwrap();
    for i in 0..6u64 {
        let (out, _) = pool.infer_keyed(i, vec![i as f32, 0.0, 1.0]).unwrap();
        assert_eq!(out, vec![2.0 * i as f32, 0.0, 2.0]);
    }
    let stats = pool.shutdown().unwrap();
    assert_eq!(stats.aggregate.cache_misses, 0, "warm cache: no cold compile while serving");
    assert!(stats.aggregate.cache_hits >= 1);
    assert_eq!(service.cold_compiles(), 1, "still just the warmup compile");
}

/// End-to-end regression for the oversized batch policy: the pool's
/// default-config shape (`BatchPolicy::max_batch = 8` against an
/// artifact batch of 4) must split, serve every request, and never
/// panic a worker.
#[test]
fn pool_survives_policy_larger_than_artifact_batch() {
    let dir = TempDir::new("pool-split");
    std::fs::write(dir.path().join("double.hlo.txt"), DOUBLE_HLO).unwrap();
    let mut cfg = base_config();
    cfg.policy = BatchPolicy::default(); // max_batch 8 > batch 4: the bug's shape
    assert!(cfg.policy.max_batch > cfg.batch);
    let pool = ServingPool::start(
        dir.path(),
        cfg,
        PoolConfig { workers: 2, queue_depth: 32, ..PoolConfig::default() },
    )
    .unwrap();
    let pending: Vec<_> = (0..24)
        .map(|i| pool.infer_keyed_async(7, vec![i as f32, 0.5, 1.5]).unwrap())
        .collect();
    for (i, rx) in pending.into_iter().enumerate() {
        assert_eq!(
            rx.recv().expect("worker alive").unwrap(),
            vec![2.0 * i as f32, 1.0, 3.0]
        );
    }
    let stats = pool.shutdown().expect("no worker panicked");
    assert_eq!(stats.aggregate.requests, 24);
}

/// Aggregate stats merge bounded latency summaries from every worker.
#[test]
fn aggregate_stats_fold_worker_summaries() {
    let dir = TempDir::new("pool-agg");
    std::fs::write(dir.path().join("double.hlo.txt"), DOUBLE_HLO).unwrap();
    let pool = ServingPool::start(
        dir.path(),
        base_config(),
        PoolConfig { workers: 2, queue_depth: 16, ..PoolConfig::default() },
    )
    .unwrap();
    for i in 0..10u64 {
        pool.infer_keyed(i, vec![0.5; 3]).unwrap();
    }
    let stats = pool.shutdown().unwrap();
    assert_eq!(stats.aggregate.requests, 10);
    let total_batches: usize = stats.per_worker.iter().map(|w| w.batches).sum();
    assert_eq!(stats.aggregate.batches, total_batches);
    assert_eq!(stats.aggregate.exec_us.count(), total_batches as u64);
    assert!(stats.aggregate.exec_us.max_us() >= stats.per_worker[0].exec_us.max_us());
}
