//! Memory-planner acceptance suite: the planned, specialized,
//! block-parallel VM must be **bit-identical** to the PR-2 boxed VM —
//! outputs and launch ledgers — on every corpus graph and benchmark
//! model, while packing values into a strictly smaller arena wherever
//! lifetimes allow, and never letting lifetime-overlapping values
//! share arena bytes.

use fusion_stitching::coordinator::pipeline::{compile_module, FusionMode, PipelineConfig};
use fusion_stitching::corpus::generator::{generate_models, generate_overflow_models, CorpusConfig};
use fusion_stitching::exec::memplan;
use fusion_stitching::exec::{ExecArena, StitchedExecutable};
use fusion_stitching::gpusim::DeviceConfig;
use fusion_stitching::hlo::Module;
use fusion_stitching::schedule::PerfLibrary;

fn mini_corpus() -> Vec<Module> {
    let cfg = CorpusConfig {
        seed: 946,
        models: 16,
        ops_per_model: (8, 24),
        max_width_log2: 6,
    };
    generate_models(&cfg)
        .into_iter()
        .map(|c| {
            let name = c.name.clone();
            Module::new(name, c)
        })
        .collect()
}

fn fill(n: usize, seed: u64) -> Vec<f32> {
    (0..n)
        .map(|i| {
            let h = (i as u64).wrapping_mul(2654435761).wrapping_add(seed.wrapping_mul(97));
            ((h % 1000) as f32) / 1000.0 - 0.5
        })
        .collect()
}

fn inputs_for(module: &Module, seed: u64) -> Vec<Vec<f32>> {
    module
        .entry
        .parameters()
        .into_iter()
        .enumerate()
        .map(|(k, id)| {
            let elems = module.entry.get(id).shape.num_elements() as usize;
            fill(elems, seed + k as u64)
        })
        .collect()
}

fn lower(module: &Module, mode: FusionMode, fuse_batch_dot: bool) -> StitchedExecutable {
    let mut lib = PerfLibrary::new(DeviceConfig::pascal());
    let mut cfg = PipelineConfig::default();
    cfg.deep.fuse_batch_dot = fuse_batch_dot;
    let compiled = compile_module(module, mode, &mut lib, &cfg)
        .unwrap_or_else(|e| panic!("{}: compile failed: {e:#}", module.name));
    match compiled.executable {
        Some(exe) => (*exe).clone(),
        None => panic!("{}: did not lower: {:?}", module.name, compiled.exec_error),
    }
}

/// Execution sweep: the corpus plus the light Table 2 models (heavy
/// library dots make NMT/RNN/BiRNN impractical to *run* repeatedly in
/// debug builds — `make bench-vm` covers all six in release).
fn suite() -> Vec<(Module, bool)> {
    let mut all: Vec<(Module, bool)> = mini_corpus().into_iter().map(|m| (m, false)).collect();
    for name in ["LR", "W2V", "Speech"] {
        let (meta, module) = fusion_stitching::models::by_name(name).unwrap();
        all.push((module, meta.fuse_batch_dot));
    }
    all
}

/// Planning-only sweep (no execution): the corpus plus all six
/// benchmarks — compiling and planning NMT in debug is cheap — plus the
/// overflow tail, whose kernels carry global-tier spill regions the
/// planner must pack like any other value.
fn plan_suite() -> Vec<(Module, bool)> {
    let mut all: Vec<(Module, bool)> = mini_corpus().into_iter().map(|m| (m, false)).collect();
    for (meta, module) in fusion_stitching::models::all_benchmarks() {
        all.push((module, meta.fuse_batch_dot));
    }
    for c in generate_overflow_models() {
        let name = c.name.clone();
        all.push((Module::new(name, c), false));
    }
    all
}

#[test]
fn planned_parallel_vm_is_bit_identical_to_boxed_vm() {
    for (i, (module, fuse_bd)) in suite().into_iter().enumerate() {
        let inputs = inputs_for(&module, 9000 + i as u64);
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        for mode in [FusionMode::XlaBaseline, FusionMode::FusionStitching] {
            let exe = lower(&module, mode, fuse_bd);
            let (boxed_out, boxed_ledger) = exe
                .run_boxed(&inputs)
                .unwrap_or_else(|e| panic!("{}: boxed run failed: {e:#}", module.name));
            // Force multi-threaded block execution even on small CI
            // machines: determinism must not depend on the core count.
            let mut arena = ExecArena::with_threads(4);
            let mut fast_out = Vec::new();
            let fast_ledger = exe
                .run_into(&refs, &mut arena, &mut fast_out)
                .unwrap_or_else(|e| panic!("{}: planned run failed: {e:#}", module.name));
            assert_eq!(
                fast_ledger, boxed_ledger,
                "{} {mode:?}: launch ledger changed",
                module.name
            );
            assert_eq!(fast_out.len(), boxed_out.len(), "{}: output size", module.name);
            for (k, (a, b)) in fast_out.iter().zip(&boxed_out).enumerate() {
                assert!(
                    a.to_bits() == b.to_bits(),
                    "{} {mode:?}: element {k} differs: {a} vs {b}",
                    module.name
                );
            }
        }
    }
}

#[test]
fn overlapping_lifetimes_never_share_arena_ranges_corpus_wide() {
    for (module, fuse_bd) in plan_suite() {
        let exe = lower(&module, FusionMode::FusionStitching, fuse_bd);
        let lives = memplan::liveness(&exe);
        let plan = &exe.mem;
        let live_slots: Vec<(usize, memplan::ValueLife, memplan::BufSlot)> = (0..lives.len())
            .filter_map(|v| Some((v, lives[v]?, plan.slots[v]?)))
            .collect();
        for (a, (va, la, sa)) in live_slots.iter().enumerate() {
            assert_eq!(sa.elems, la.elems, "{}: %{va} slot size", module.name);
            assert!(
                sa.off + sa.elems <= plan.arena_elems,
                "{}: %{va} range exceeds the arena",
                module.name
            );
            for (vb, lb, sb) in live_slots.iter().skip(a + 1) {
                if la.overlaps(lb) {
                    let disjoint = sa.off + sa.elems <= sb.off || sb.off + sb.elems <= sa.off;
                    assert!(
                        disjoint,
                        "{}: live values %{va} and %{vb} share arena bytes",
                        module.name
                    );
                }
            }
        }
        // The plan never wastes space versus the boxed layout.
        assert!(plan.arena_elems <= plan.total_value_elems, "{}", module.name);
    }
}

#[test]
fn spill_regions_get_planned_slots_and_fences_order_phases_at_any_thread_count() {
    // Global-tier kernels materialize an intermediate in a spill region
    // behind a grid fence. The memory planner must treat those regions
    // like any other value (an arena slot, lifetime-disjoint from
    // everything live — the corpus-wide overlap test covers that via
    // `plan_suite`), and the block-parallel VM must keep producer and
    // consumer phases ordered whatever the worker count.
    use fusion_stitching::exec::bytecode::BlockStep;
    use fusion_stitching::exec::Launch;

    for c in generate_overflow_models() {
        let name = c.name.clone();
        let module = Module::new(name, c);
        let exe = lower(&module, FusionMode::FusionStitching, false);
        let lives = memplan::liveness(&exe);

        let mut spill_kernels = 0usize;
        for l in &exe.launches {
            let Launch::Kernel(k) = l else { continue };
            if k.spills.is_empty() {
                continue;
            }
            spill_kernels += 1;
            assert!(
                k.steps.iter().any(|s| matches!(s, BlockStep::GridFence)),
                "{}: a spilling kernel must fence its phases",
                module.name
            );
            // A fence is never the first step: something must be
            // produced before anything is ordered after it.
            assert!(
                !matches!(k.steps.first(), Some(BlockStep::GridFence)),
                "{}: leading fence guards nothing",
                module.name
            );
            for &(id, elems) in &k.spills {
                let life = lives[id.0]
                    .unwrap_or_else(|| panic!("{}: spill %{} has no lifetime", module.name, id.0));
                assert_eq!(life.elems, elems.max(1), "{}: spill size", module.name);
                let slot = exe.mem.slots[id.0]
                    .unwrap_or_else(|| panic!("{}: spill %{} has no arena slot", module.name, id.0));
                assert_eq!(slot.elems, life.elems, "{}: spill slot size", module.name);
            }
        }
        assert!(spill_kernels > 0, "{}: overflow model must spill", module.name);

        // Fence ordering is a parallel-execution property: the join
        // between phases is the fence, so outputs and ledgers must not
        // depend on how blocks spread over workers.
        let inputs = inputs_for(&module, 321);
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let (boxed_out, boxed_ledger) = exe.run_boxed(&inputs).unwrap();
        assert!(boxed_ledger.fences > 0, "{}: fences must be executed", module.name);
        for threads in [1usize, 2, 4] {
            let mut arena = ExecArena::with_threads(threads);
            let mut out = Vec::new();
            let ledger = exe.run_into(&refs, &mut arena, &mut out).unwrap();
            assert_eq!(ledger, boxed_ledger, "{} @ {threads} threads", module.name);
            assert_eq!(out.len(), boxed_out.len(), "{}", module.name);
            for (k, (a, b)) in out.iter().zip(&boxed_out).enumerate() {
                assert!(
                    a.to_bits() == b.to_bits(),
                    "{} @ {threads} threads: element {k}: {a} vs {b}",
                    module.name
                );
            }
        }
    }
}

#[test]
fn arena_reuse_reaches_zero_allocation_steady_state() {
    for (module, fuse_bd) in suite().into_iter().take(6) {
        let exe = lower(&module, FusionMode::FusionStitching, fuse_bd);
        let inputs = inputs_for(&module, 77);
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let mut arena = ExecArena::default();
        let mut out = Vec::new();
        for _ in 0..4 {
            exe.run_into(&refs, &mut arena, &mut out).unwrap();
        }
        assert_eq!(arena.grows(), 1, "{}: arena grew after warmup", module.name);
        assert_eq!(arena.reuses(), 3, "{}: reuse counter", module.name);
        // The plan never exceeds the boxed VM's footprint; crafted
        // graphs with genuine compression are unit-tested in
        // `exec::memplan` (`sequential_chain_reuses_retired_ranges`).
        assert!(exe.mem.arena_elems <= exe.mem.total_value_elems, "{}", module.name);
    }
}

#[test]
fn one_arena_serves_many_executables() {
    // A serving worker's arena is shared across whatever executables
    // its shard routes; growth is monotone, reuse kicks in per plan.
    let mods = suite();
    let mut arena = ExecArena::default();
    let mut out = Vec::new();
    let mut exes = Vec::new();
    for (module, fuse_bd) in mods.into_iter().take(4) {
        let inputs = inputs_for(&module, 5);
        let exe = lower(&module, FusionMode::FusionStitching, fuse_bd);
        exes.push((exe, inputs));
    }
    for (exe, inputs) in &exes {
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        exe.run_into(&refs, &mut arena, &mut out).unwrap();
    }
    let grows_first_pass = arena.grows();
    // Second sweep: the arena already covers every plan's high-water
    // mark, so no run allocates.
    for (exe, inputs) in &exes {
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        exe.run_into(&refs, &mut arena, &mut out).unwrap();
    }
    assert_eq!(arena.grows(), grows_first_pass, "second sweep must be allocation-free");
    assert!(arena.reuses() >= 4);
}
