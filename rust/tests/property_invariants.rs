//! Property-style tests: pipeline invariants over hundreds of random
//! graphs drawn from the deterministic generator in
//! `fusion_stitching::testutil` (proptest is unavailable in this offline
//! image; the methodology is the same, with explicit seeds for
//! reproducibility).

use fusion_stitching::analysis::{DominatorTree, SpanAnalysis};
use fusion_stitching::coordinator::pipeline::{compile_module, FusionMode, PipelineConfig};
use fusion_stitching::fusion::{deep_fusion, xla_baseline_fusion, DeepFusionConfig};
use fusion_stitching::gpusim::DeviceConfig;
use fusion_stitching::hlo::printer::print_module;
use fusion_stitching::hlo::{parser::parse_module, verifier::verify_computation, Module};
use fusion_stitching::schedule::{propagate, OpSchedule, PerfLibrary, Schedule};
use fusion_stitching::testutil::GraphGen;

const CASES: usize = 120;

#[test]
fn prop_both_fusion_passes_produce_valid_partitions() {
    let mut gen = GraphGen::new(0xF00D);
    let mut lib = PerfLibrary::new(DeviceConfig::pascal());
    for case in 0..CASES {
        let comp = gen.gen();
        verify_computation(&comp).unwrap();
        let base = xla_baseline_fusion(&comp);
        base.validate(&comp).unwrap_or_else(|e| panic!("case {case} baseline: {e:#}"));
        let (deep, _) = deep_fusion(&comp, &mut lib, &DeepFusionConfig::default());
        deep.validate(&comp).unwrap_or_else(|e| panic!("case {case} deep: {e:#}"));
        // fusion monotonicity
        assert!(
            deep.generated_kernel_count(&comp) <= comp.unfused_kernel_count(),
            "case {case}"
        );
    }
}

#[test]
fn prop_deep_fusion_never_beats_baseline_on_launches_backwards() {
    // Deep fusion's kernel count is ≤ the baseline's on every graph.
    let mut gen = GraphGen::new(0xBEEF);
    let mut lib = PerfLibrary::new(DeviceConfig::pascal());
    for case in 0..CASES {
        let comp = gen.gen();
        let base = xla_baseline_fusion(&comp).generated_kernel_count(&comp);
        let (deep, _) = deep_fusion(&comp, &mut lib, &DeepFusionConfig::default());
        let d = deep.generated_kernel_count(&comp);
        assert!(d <= base, "case {case}: deep {d} > baseline {base}");
    }
}

#[test]
fn prop_schedule_propagation_agrees_on_grid() {
    // For every deep-fusion group with a sound plan, all scheduled
    // members share the group's block count (the block-composition
    // precondition).
    let mut gen = GraphGen::new(0xCAFE);
    let mut lib = PerfLibrary::new(DeviceConfig::pascal());
    let cfg = PipelineConfig::default();
    for case in 0..60 {
        let comp = gen.gen();
        let module = Module::new(format!("prop{case}"), comp);
        let compiled =
            compile_module(&module, FusionMode::FusionStitching, &mut lib, &cfg).unwrap();
        for (gid, kernel) in compiled.generated_group_ids.iter().zip(&compiled.kernels) {
            let group = &compiled.plan.groups[*gid];
            let roots: Vec<_> = group
                .roots
                .iter()
                .map(|&r| (r, pick_root_schedule(kernel.blocks, &module, r)))
                .collect();
            let _ = roots; // grid agreement is enforced below via emitter state
            for op in &kernel.ops {
                if let fusion_stitching::codegen::kernel_plan::EmitterKind::Stitched(s) =
                    &op.emitter
                {
                    let shape = &module.entry.get(op.id).shape;
                    assert_eq!(
                        s.blocks(shape),
                        kernel.blocks,
                        "case {case}: op {} grid disagrees",
                        op.id
                    );
                }
            }
        }
    }
}

fn pick_root_schedule(_blocks: u64, _m: &Module, _r: fusion_stitching::hlo::InstrId) -> () {}

#[test]
fn prop_propagation_fallback_always_satisfiable() {
    // §4.3: the (0,1,Row) single-block schedule is valid for ANY fused
    // computation whose members are fusable and connected to the root.
    let mut gen = GraphGen::new(0xABCD);
    for _ in 0..CASES {
        let comp = gen.gen();
        // take the root's producer-closure restricted to fusable ops
        let root = comp.root();
        if !comp.get(root).opcode.is_fusable() {
            continue;
        }
        let mut members = std::collections::HashSet::new();
        let mut stack = vec![root];
        while let Some(id) = stack.pop() {
            if !comp.get(id).opcode.is_fusable() || !members.insert(id) {
                continue;
            }
            for &op in &comp.get(id).operands {
                if comp.get(op).opcode.is_fusable() && !comp.get(op).opcode.is_free() {
                    stack.push(op);
                }
            }
        }
        members.retain(|&id| comp.depends_on(root, id));
        let res = propagate(&comp, &members, &[(root, Schedule::fallback())]);
        let prop = res.expect("fallback schedule must satisfy any connected group");
        assert_eq!(prop.blocks, 1);
        for st in prop.assignment.values() {
            if let OpSchedule::Scheduled(s) = st {
                assert_eq!(s.sword, 1);
            }
        }
    }
}

#[test]
fn prop_parser_roundtrips_random_graphs() {
    let mut gen = GraphGen::new(0x5EED);
    for case in 0..CASES {
        let comp = gen.gen();
        let module = Module::new(format!("rt{case}"), comp);
        let text = print_module(&module);
        let parsed = parse_module(&text).unwrap_or_else(|e| panic!("case {case}: {e:#}"));
        assert_eq!(parsed.entry.len(), module.entry.len());
        for id in module.entry.ids() {
            let a = module.entry.get(id);
            let b = parsed.entry.get(id);
            assert_eq!(a.opcode, b.opcode, "case {case} at {id}");
            assert_eq!(a.shape, b.shape, "case {case} at {id}");
            assert_eq!(a.operands, b.operands, "case {case} at {id}");
        }
        // and the reparse verifies
        verify_computation(&parsed.entry).unwrap();
    }
}

#[test]
fn prop_span_layers_are_antichains() {
    // No data dependence within a (frame, span) layer.
    let mut gen = GraphGen::new(0x1234);
    for _ in 0..CASES {
        let comp = gen.gen();
        let spans = SpanAnalysis::run(&comp);
        for frame in spans.frames() {
            for s in 0..=spans.critical_path(frame) {
                let layer = spans.layer(frame, s);
                for &a in layer {
                    for &op in &comp.get(a).operands {
                        if comp.get(op).frame == frame {
                            assert_ne!(
                                spans.span_of(op),
                                s,
                                "operand in same layer as user"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn prop_dominance_is_a_partial_order_on_chains() {
    let mut gen = GraphGen::new(0x9999);
    for _ in 0..40 {
        let comp = gen.gen();
        let root = comp.root();
        let dt = DominatorTree::build(&comp, root, None);
        for id in dt.nodes() {
            // root dominates everything reachable; reflexivity holds.
            assert!(dt.dominates(root, id));
            assert!(dt.dominates(id, id));
            // idom is itself a dominator
            if let Some(d) = dt.idom(id) {
                assert!(dt.dominates(d, id));
            }
        }
    }
}

#[test]
fn prop_shm_plans_respect_budget_or_reject() {
    // compile_module either produces kernels within the budget, or the
    // feedback loop rejected the grouping earlier — never an over-budget
    // kernel.
    let mut gen = GraphGen::new(0x7777);
    let mut lib = PerfLibrary::new(DeviceConfig::pascal());
    let cfg = PipelineConfig::default();
    let limit = cfg.deep.device.shared_mem_kernel_limit;
    for case in 0..60 {
        let comp = gen.gen();
        let module = Module::new(format!("shm{case}"), comp);
        let compiled =
            compile_module(&module, FusionMode::FusionStitching, &mut lib, &cfg).unwrap();
        for k in &compiled.kernels {
            assert!(k.shm.total_bytes <= limit, "case {case}: {} B", k.shm.total_bytes);
        }
    }
}
