//! Integration tests for deadline-aware serving: slack-based admission
//! sheds infeasible requests with a structured reply, admitted requests
//! record signed slack, and tearing a pool down mid-load answers every
//! in-flight client (no hung `recv`).

use fusion_stitching::coordinator::batcher::BatchPolicy;
use fusion_stitching::coordinator::{
    DeadlinePolicy, PoolConfig, Rejection, ServerConfig, ServingCoordinator, ServingPool,
};
use fusion_stitching::testutil::TempDir;
use std::time::Duration;

/// Identity-ish artifact: doubles a [4, 3] batch.
const DOUBLE_HLO: &str = r#"HloModule double, entry_computation_layout={(f32[4,3]{1,0})->(f32[4,3]{1,0})}

ENTRY main {
  p0 = f32[4,3]{1,0} parameter(0)
  sum = f32[4,3]{1,0} add(p0, p0)
  ROOT t = (f32[4,3]{1,0}) tuple(sum)
}
"#;

fn config(deadline: Option<DeadlinePolicy>) -> ServerConfig {
    ServerConfig {
        artifact: "double".into(),
        batch: 4,
        in_elems_per_request: 3,
        out_elems_per_request: 3,
        input_dims: vec![4, 3],
        policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) },
        compile: None,
        buckets: None,
        trace: None,
        deadline,
        faults: None,
    }
}

fn write_artifact(dir: &TempDir) {
    std::fs::write(dir.path().join("double.hlo.txt"), DOUBLE_HLO).unwrap();
}

/// A deadline the predicted service time cannot possibly meet is shed
/// before execution with a structured `DeadlineInfeasible` reply, and
/// the shed is counted under `rejects.deadline` — while deadline-free
/// traffic on the same pool keeps being served.
#[test]
fn infeasible_deadline_sheds_with_structured_reply() {
    let dir = TempDir::new("deadline-shed");
    write_artifact(&dir);
    // No default deadline: only the explicit per-request one sheds.
    let policy = DeadlinePolicy {
        bootstrap_service_us: 50_000.0, // predict 50ms of service…
        ..DeadlinePolicy::default()
    };
    let pool = ServingPool::start(
        dir.path(),
        config(Some(policy)),
        PoolConfig { workers: 1, ..PoolConfig::default() },
    )
    .unwrap();

    // …against a 1ms deadline: hopeless, must shed.
    let err = pool
        .infer_with_deadline(vec![1.0, 2.0, 3.0], Duration::from_millis(1))
        .expect_err("infeasible deadline must not be served");
    assert_eq!(err.downcast_ref::<Rejection>(), Some(&Rejection::DeadlineInfeasible), "{err:#}");
    assert!(err.to_string().contains("shed"), "{err:#}");

    // A deadline-free request on the same pool is still served.
    let (out, _) = pool.infer(vec![1.0, 2.0, 3.0]).unwrap();
    assert_eq!(out, vec![2.0, 4.0, 6.0]);

    let stats = pool.shutdown().unwrap();
    assert_eq!(stats.aggregate.rejects.deadline, 1, "shed counted: {:?}", stats.aggregate.rejects);
    assert_eq!(stats.aggregate.requests, 1, "only the deadline-free request executed");
}

/// A generous deadline is admitted, served within budget, and leaves a
/// positive-slack sample behind — no misses, no sheds.
#[test]
fn generous_deadline_served_with_recorded_slack() {
    let dir = TempDir::new("deadline-ok");
    write_artifact(&dir);
    let policy = DeadlinePolicy {
        default_deadline: Some(Duration::from_secs(10)),
        ..DeadlinePolicy::default()
    };
    let pool = ServingPool::start(
        dir.path(),
        config(Some(policy)),
        PoolConfig { workers: 1, ..PoolConfig::default() },
    )
    .unwrap();
    for i in 0..6u64 {
        let (out, _) = pool.infer_keyed(i, vec![i as f32, 0.0, 1.0]).unwrap();
        assert_eq!(out, vec![2.0 * i as f32, 0.0, 2.0]);
    }
    let stats = pool.shutdown().unwrap();
    assert_eq!(stats.aggregate.requests, 6);
    assert_eq!(stats.aggregate.rejects.total(), 0, "{:?}", stats.aggregate.rejects);
    assert_eq!(stats.aggregate.deadline_misses, 0);
    assert!(
        stats.aggregate.slack_us.count() >= 6,
        "every admitted deadline leaves a slack sample: {}",
        stats.aggregate.slack_us.count()
    );
    assert!(stats.aggregate.slack_us.mean_us() > 0.0, "10s deadlines leave positive slack");
}

/// The single-worker coordinator honors explicit per-request deadlines
/// through the same slack admission as the pool.
#[test]
fn coordinator_sheds_infeasible_deadline() {
    let dir = TempDir::new("deadline-coord");
    write_artifact(&dir);
    let policy =
        DeadlinePolicy { bootstrap_service_us: 50_000.0, ..DeadlinePolicy::default() };
    let srv = ServingCoordinator::start(dir.path(), config(Some(policy))).unwrap();
    let err = srv
        .infer_with_deadline(vec![1.0, 2.0, 3.0], Duration::from_millis(1))
        .expect_err("infeasible deadline must be shed");
    assert_eq!(err.downcast_ref::<Rejection>(), Some(&Rejection::DeadlineInfeasible), "{err:#}");
    let (out, _) = srv.infer(vec![0.5, 1.5, 2.5]).unwrap();
    assert_eq!(out, vec![1.0, 3.0, 5.0]);
    let stats = srv.shutdown().unwrap();
    assert_eq!(stats.rejects.deadline, 1);
}

/// Graceful shutdown under load: dropping the pool with a queue full of
/// unanswered requests must drain and answer every one of them —
/// a client blocked on `recv` gets a reply (or a structured error),
/// never a hang.
#[test]
fn dropping_pool_mid_load_answers_every_client() {
    let dir = TempDir::new("deadline-drop");
    write_artifact(&dir);
    let pool = ServingPool::start(
        dir.path(),
        config(None),
        PoolConfig { workers: 2, ..PoolConfig::default() },
    )
    .unwrap();
    let receivers: Vec<_> = (0..64)
        .map(|i| {
            let key = (i % 8) as u64;
            pool.infer_keyed_async(key, vec![i as f32, 0.5, 1.5]).unwrap()
        })
        .collect();
    // Drop with every request still in flight: teardown must close the
    // queues and let the workers drain them.
    drop(pool);
    for (i, rx) in receivers.into_iter().enumerate() {
        let reply = rx
            .recv_timeout(Duration::from_secs(10))
            .unwrap_or_else(|e| panic!("client {i} hung on shutdown: {e}"));
        let out = reply.unwrap_or_else(|e| panic!("request {i} not served: {e:#}"));
        assert_eq!(out, vec![2.0 * i as f32, 1.0, 3.0]);
    }
}
