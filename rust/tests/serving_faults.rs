//! Fault-injection integration tests (run with `--features faults`):
//! under a seeded [`FaultPlan`] the pool must keep serving — no hung
//! clients, every request answered or shed with a structured reason —
//! and the observed counters must reconcile with the injected ground
//! truth.

#![cfg(feature = "faults")]

use fusion_stitching::coordinator::batcher::BatchPolicy;
use fusion_stitching::coordinator::server::CompileOptions;
use fusion_stitching::coordinator::{
    DeadlinePolicy, FaultPlan, FusionMode, PipelineConfig, PoolConfig, Rejection, ServerConfig,
    ServingPool,
};
use fusion_stitching::models;
use fusion_stitching::testutil::TempDir;
use std::sync::Arc;
use std::time::Duration;

/// Identity-ish artifact: doubles a [4, 3] batch.
const DOUBLE_HLO: &str = r#"HloModule double, entry_computation_layout={(f32[4,3]{1,0})->(f32[4,3]{1,0})}

ENTRY main {
  p0 = f32[4,3]{1,0} parameter(0)
  sum = f32[4,3]{1,0} add(p0, p0)
  ROOT t = (f32[4,3]{1,0}) tuple(sum)
}
"#;

fn config(faults: Arc<FaultPlan>) -> ServerConfig {
    ServerConfig {
        artifact: "double".into(),
        batch: 4,
        in_elems_per_request: 3,
        out_elems_per_request: 3,
        input_dims: vec![4, 3],
        policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) },
        compile: None,
        buckets: None,
        trace: None,
        deadline: None,
        faults: Some(faults),
    }
}

fn write_artifact(dir: &TempDir) {
    std::fs::write(dir.path().join("double.hlo.txt"), DOUBLE_HLO).unwrap();
}

/// An injected worker panic mid-load: the supervisor respawns the
/// shard, queued requests on the dead shard are shed with a structured
/// reply (never silently dropped), and the pool keeps serving. The
/// respawn counter reconciles with the plan's injected-panic count.
#[test]
fn injected_panic_respawns_worker_and_loses_no_client() {
    let dir = TempDir::new("faults-panic");
    write_artifact(&dir);
    let plan = Arc::new(FaultPlan::new(7).panic_after(2));
    let pool = ServingPool::start(
        dir.path(),
        config(plan.clone()),
        PoolConfig { workers: 2, ..PoolConfig::default() },
    )
    .unwrap();

    let receivers: Vec<_> = (0..40)
        .map(|i| {
            let key = (i % 8) as u64;
            pool.infer_keyed_async(key, vec![i as f32, 0.5, 1.5]).unwrap()
        })
        .collect();
    let (mut served, mut shed) = (0u64, 0u64);
    for (i, rx) in receivers.into_iter().enumerate() {
        let reply = rx
            .recv_timeout(Duration::from_secs(10))
            .unwrap_or_else(|e| panic!("client {i} hung across the injected panic: {e}"));
        match reply {
            Ok(out) => {
                assert_eq!(out, vec![2.0 * i as f32, 1.0, 3.0]);
                served += 1;
            }
            Err(e) => {
                assert_eq!(
                    e.downcast_ref::<Rejection>(),
                    Some(&Rejection::Shed),
                    "only structured sheds are acceptable: {e:#}"
                );
                shed += 1;
            }
        }
    }
    assert_eq!(served + shed, 40, "every client answered");

    // The supervisor's respawn is asynchronous; wait for it to land.
    let mut respawned = false;
    for _ in 0..200 {
        if pool.stats().respawns >= 1 {
            respawned = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    assert!(respawned, "injected panic must be followed by a respawn");

    // The pool still serves after the respawn.
    let (out, _) = pool.infer_keyed(3, vec![1.0, 2.0, 3.0]).unwrap();
    assert_eq!(out, vec![2.0, 4.0, 6.0]);

    let stats = pool.shutdown().unwrap();
    assert_eq!(plan.injected_panics(), 1, "the panic point fires exactly once");
    assert_eq!(stats.respawns, 1, "one respawn per injected panic");
    assert_eq!(
        stats.aggregate.requests + stats.aggregate.rejected,
        41,
        "accounting covers the load and the post-respawn probe: {:?}",
        stats.aggregate
    );
    assert_eq!(stats.aggregate.rejects.shed, shed, "shed counter matches shed replies");
}

/// An injected slow-kernel burst against a tight deadline: the slack
/// estimator absorbs the measured slowdown and starts shedding
/// infeasible requests, while everything already admitted is still
/// answered (as counted deadline misses, not hangs).
#[test]
fn slow_kernels_drive_deadline_sheds_not_hangs() {
    let dir = TempDir::new("faults-slow");
    write_artifact(&dir);
    // Every batch sleeps 20ms — far beyond the 5ms deadline.
    let plan = Arc::new(FaultPlan::new(3).slow_kernels(0, 10_000, 20_000, 0));
    let mut cfg = config(plan.clone());
    cfg.deadline = Some(DeadlinePolicy {
        default_deadline: Some(Duration::from_millis(5)),
        ..DeadlinePolicy::default()
    });
    let pool = ServingPool::start(
        dir.path(),
        cfg,
        PoolConfig { workers: 1, ..PoolConfig::default() },
    )
    .unwrap();

    let receivers: Vec<_> = (0..30)
        .map(|i| pool.infer_keyed_async(1, vec![i as f32, 0.0, 1.0]).unwrap())
        .collect();
    let (mut served, mut shed) = (0u64, 0u64);
    for (i, rx) in receivers.into_iter().enumerate() {
        let reply = rx
            .recv_timeout(Duration::from_secs(30))
            .unwrap_or_else(|e| panic!("client {i} hung under slow kernels: {e}"));
        match reply {
            Ok(out) => {
                assert_eq!(out, vec![2.0 * i as f32, 0.0, 2.0]);
                served += 1;
            }
            Err(e) => {
                assert_eq!(
                    e.downcast_ref::<Rejection>(),
                    Some(&Rejection::DeadlineInfeasible),
                    "{e:#}"
                );
                shed += 1;
            }
        }
    }
    assert_eq!(served + shed, 30, "zero silent timeouts");
    assert!(served >= 1, "bootstrap-admitted requests are still answered");
    assert!(shed >= 1, "the measured slowdown must start shedding");
    assert!(plan.injected_slow() >= 1, "the slow window actually fired");

    let stats = pool.shutdown().unwrap();
    assert_eq!(stats.aggregate.requests as u64, served);
    assert_eq!(stats.aggregate.rejects.deadline, shed, "{:?}", stats.aggregate.rejects);
    assert!(
        stats.aggregate.deadline_misses >= 1,
        "admitted-but-slow batches land as counted misses"
    );
}

/// Injected cold-compile failures: the first attempt fails and is
/// negatively cached, a retry inside the backoff window fast-fails
/// without re-running the pipeline, and a retry after the window
/// recovers — serving continues on the artifact interpreter throughout.
#[test]
fn injected_compile_faults_fast_fail_then_recover() {
    let dir = TempDir::new("faults-compile");
    write_artifact(&dir);
    let plan = Arc::new(FaultPlan::new(11).fail_compiles(1));
    let (meta, nmt) = models::by_name("NMT").unwrap();
    let mut pipeline = PipelineConfig::default();
    pipeline.deep.fuse_batch_dot = meta.fuse_batch_dot;
    let mut cfg = config(plan.clone());
    cfg.compile = Some(CompileOptions {
        module: nmt,
        mode: FusionMode::FusionStitching,
        pipeline,
        use_stitched_backend: false,
        specialize: None,
    });
    let pool = ServingPool::start(
        dir.path(),
        cfg,
        PoolConfig { workers: 1, ..PoolConfig::default() },
    )
    .unwrap();
    let service = pool.compile_service().unwrap().clone();
    // A wide, deterministic backoff window: the second request lands
    // inside it (fast-fail), the post-sleep request lands beyond it.
    service.set_failure_backoff(Duration::from_millis(500), Duration::from_millis(500));

    // First batch: the injected failure. Still served (interpreter).
    let (out, _) = pool.infer_keyed(1, vec![1.0, 2.0, 3.0]).unwrap();
    assert_eq!(out, vec![2.0, 4.0, 6.0]);
    assert_eq!(plan.injected_compile_fails(), 1);

    // Second batch, inside the backoff window: the negative cache
    // answers without a new pipeline attempt.
    let (out, _) = pool.infer_keyed(1, vec![0.5, 1.5, 2.5]).unwrap();
    assert_eq!(out, vec![1.0, 3.0, 5.0]);
    assert_eq!(service.compile_fast_fails(), 1, "backoff window fast-fails");
    assert_eq!(plan.compile_attempts(), 1, "no real attempt inside the window");

    // Past the window: the retry runs for real and succeeds.
    std::thread::sleep(Duration::from_millis(700));
    let (out, _) = pool.infer_keyed(1, vec![2.0, 0.0, -2.0]).unwrap();
    assert_eq!(out, vec![4.0, 0.0, -4.0]);

    let stats = pool.shutdown().unwrap();
    assert_eq!(plan.compile_attempts(), 2, "exactly one real retry after backoff");
    assert_eq!(stats.aggregate.compile_failures, 1, "fast-fails are not re-counted");
    assert_eq!(stats.aggregate.requests, 3);
    assert_eq!(stats.cold_compiles, Some(1), "injected failures never count as cold compiles");
    assert_eq!(stats.compile_fast_fails, Some(1));
}
