//! Property-style tests for the cost-guided fusion explorer
//! (`fusion::explore`): over the synthetic corpus, every refined plan
//! must be a valid partition, respect while-frame boundaries, and —
//! executed on the stitched VM — never pay more kernel launches than
//! the greedy plan it refined.

use fusion_stitching::coordinator::pipeline::{compile_module, FusionMode, PipelineConfig};
use fusion_stitching::corpus::generator::{generate_models, CorpusConfig};
use fusion_stitching::fusion::{deep_fusion, explore_fusion, DeepFusionConfig};
use fusion_stitching::gpusim::DeviceConfig;
use fusion_stitching::hlo::Module;
use fusion_stitching::schedule::PerfLibrary;

fn corpus() -> Vec<Module> {
    let cfg = CorpusConfig { seed: 946, models: 16, ops_per_model: (8, 24), max_width_log2: 6 };
    generate_models(&cfg)
        .into_iter()
        .map(|c| {
            let name = c.name.clone();
            Module::new(name, c)
        })
        .collect()
}

fn fill(n: usize, seed: u64) -> Vec<f32> {
    (0..n)
        .map(|i| {
            let h = (i as u64).wrapping_mul(2654435761).wrapping_add(seed.wrapping_mul(97));
            ((h % 1000) as f32) / 1000.0 - 0.5
        })
        .collect()
}

fn inputs_for(module: &Module, seed: u64) -> Vec<Vec<f32>> {
    module
        .entry
        .parameters()
        .into_iter()
        .enumerate()
        .map(|(k, id)| {
            let elems = module.entry.get(id).shape.num_elements() as usize;
            fill(elems, seed + k as u64)
        })
        .collect()
}

#[test]
fn prop_explored_plans_are_valid_and_frame_pure() {
    let cfg = DeepFusionConfig::default();
    for (case, module) in corpus().iter().enumerate() {
        let comp = &module.entry;
        let mut lib = PerfLibrary::new(DeviceConfig::pascal());
        let (greedy, _) = deep_fusion(comp, &mut lib, &cfg);
        let greedy_kernels = greedy.generated_kernel_count(comp);
        let (refined, _) = explore_fusion(comp, &greedy, &mut lib, &cfg);
        refined.validate(comp).unwrap_or_else(|e| panic!("case {case}: {e:#}"));
        // Frame discipline: a kernel never straddles while-loop bodies.
        for group in &refined.groups {
            let mut frames: Vec<u32> =
                group.members.iter().map(|&id| comp.get(id).frame).collect();
            frames.sort_unstable();
            frames.dedup();
            assert!(
                frames.len() <= 1,
                "case {case}: group {} spans frames {frames:?}",
                group.id
            );
        }
        // Planned launches within the greedy budget.
        assert!(
            refined.generated_kernel_count(comp) <= greedy_kernels,
            "case {case}: {} > {}",
            refined.generated_kernel_count(comp),
            greedy_kernels
        );
        assert_eq!(refined.library_call_count(), greedy.library_call_count(), "case {case}");
    }
}

#[test]
fn prop_explored_execution_never_increases_ledger_counts() {
    for (case, module) in corpus().iter().enumerate() {
        let inputs = inputs_for(module, 9000 + case as u64);
        let run = |cost_fusion: bool| {
            let mut lib = PerfLibrary::new(DeviceConfig::pascal());
            let mut cfg = PipelineConfig::default();
            cfg.deep.cost_fusion = cost_fusion;
            let compiled = compile_module(module, FusionMode::FusionStitching, &mut lib, &cfg)
                .unwrap_or_else(|e| panic!("case {case}: compile failed: {e:#}"));
            let exe = compiled
                .executable
                .unwrap_or_else(|| panic!("case {case}: did not lower: {:?}", compiled.exec_error));
            let (_, ledger) = exe
                .run(&inputs)
                .unwrap_or_else(|e| panic!("case {case}: run failed: {e:#}"));
            ledger
        };
        let greedy = run(false);
        let explored = run(true);
        assert!(
            explored.total_launches() <= greedy.total_launches(),
            "case {case}: explored launched {} vs greedy {}",
            explored.total_launches(),
            greedy.total_launches()
        );
        assert_eq!(explored.library, greedy.library, "case {case}: library calls changed");
    }
}
