//! Feedback-directed autotuning, end to end:
//!
//! - the [`CostOracle`] seam is cost-neutral — every pre-existing
//!   consumer produces bit-identical plans under [`ModeledCost`], and a
//!   measured overlay with no samples behaves exactly like the model;
//! - measured write-backs that contradict the model change the plan:
//!   `reexplore_and_swap` recompiles under [`CostSource::Measured`] and
//!   atomically replaces the resident artifact (generation bump,
//!   eviction-not-miss accounting);
//! - a live [`ServingPool`] with the autotune thread hot-swaps the
//!   served module mid-traffic with zero dropped or failed requests.

use fusion_stitching::coordinator::batcher::BatchPolicy;
use fusion_stitching::coordinator::pipeline::compile_module;
use fusion_stitching::coordinator::server::CompileOptions;
use fusion_stitching::coordinator::{
    AutotuneConfig, FusionMode, PipelineConfig, PoolConfig, ServerConfig, ServingPool,
    SharedCompileService,
};
use fusion_stitching::fusion::{
    deep_fusion, deep_fusion_with_oracle, explore_fusion, explore_fusion_with_oracle,
};
use fusion_stitching::hlo::{GraphBuilder, Module, ReduceKind, Shape};
use fusion_stitching::models;
use fusion_stitching::obs::KernelProfile;
use fusion_stitching::schedule::{CostSource, MeasuredCost, ModeledCost, PerfLibrary};
use fusion_stitching::testutil::TempDir;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Identity-ish artifact so the pool's engine has something to parse;
/// batches execute on the stitched backend, never on this text.
const DOUBLE_HLO: &str = r#"HloModule double, entry_computation_layout={(f32[4,3]{1,0})->(f32[4,3]{1,0})}

ENTRY main {
  p0 = f32[4,3]{1,0} parameter(0)
  sum = f32[4,3]{1,0} add(p0, p0)
  ROOT t = (f32[4,3]{1,0}) tuple(sum)
}
"#;

/// A module whose modeled-optimal plan keeps >= 2 generated kernels:
/// fusing the wide elementwise producer into the scalar-rooted reduce
/// group would serialize it onto one block, so the model keeps them
/// apart — until measured feedback says both standalone kernels are
/// catastrophically slow.
fn swap_module() -> Module {
    let mut b = GraphBuilder::new("entry");
    let x = b.param("x", Shape::f32(&[1024, 256]));
    let e = b.exp(x);
    let r = b.reduce(e, &[0, 1], ReduceKind::Sum); // scalar
    let t = b.tanh(r);
    Module::new("swapdemo", b.finish(t))
}

/// Feed `wall_us` as the measured time of every generated group of a
/// compiled artifact — enough samples to clear the estimator's minimum.
fn synthetic_feedback(artifact: &fusion_stitching::coordinator::CompiledModule, wall_us: f64) -> KernelProfile {
    let seeded = artifact.profile.snapshot();
    let mut fed = KernelProfile::default();
    for (fp, g) in seeded.groups() {
        for _ in 0..16 {
            fed.record_launch(fp, g.tier, g.modeled_us, wall_us, 0, 0);
        }
    }
    fed
}

/// The acceptance differential: the refactor routed every cost consumer
/// through the oracle seam, and under [`ModeledCost`] (the default) the
/// whole pipeline must be bit-for-bit what the direct calls produced —
/// same greedy plan, same explore verdicts, same final partition.
#[test]
fn modeled_oracle_is_bit_identical_to_the_direct_path() {
    for (meta, module) in models::all_benchmarks() {
        let mut cfg = PipelineConfig::default();
        cfg.deep.fuse_batch_dot = meta.fuse_batch_dot;

        let mut lib_a = PerfLibrary::new(cfg.deep.device.clone());
        let (greedy_a, _) = deep_fusion(&module.entry, &mut lib_a, &cfg.deep);
        let (plan_a, stats_a) = explore_fusion(&module.entry, &greedy_a, &mut lib_a, &cfg.deep);

        let mut lib_b = PerfLibrary::new(cfg.deep.device.clone());
        let (greedy_b, _) =
            deep_fusion_with_oracle(&module.entry, &mut lib_b, &cfg.deep, &ModeledCost);
        let (plan_b, stats_b) =
            explore_fusion_with_oracle(&module.entry, &greedy_b, &mut lib_b, &cfg.deep, &ModeledCost);

        assert_eq!(greedy_a.digest(), greedy_b.digest(), "{}: greedy plans differ", meta.name);
        assert_eq!(plan_a.digest(), plan_b.digest(), "{}: explored plans differ", meta.name);
        assert_eq!(
            (stats_a.merges_accepted, stats_a.splits_accepted, stats_a.merges_tried, stats_a.splits_tried),
            (stats_b.merges_accepted, stats_b.splits_accepted, stats_b.merges_tried, stats_b.splits_tried),
            "{}: explore decisions differ",
            meta.name
        );
        assert_eq!(
            stats_a.modeled_after_us.to_bits(),
            stats_b.modeled_after_us.to_bits(),
            "{}: modeled totals differ",
            meta.name
        );
    }
}

/// A measured overlay with no samples is the model: compiling under
/// [`CostSource::Measured`] against an empty perf library must reach
/// exactly the modeled plan (the oracle only ever *overrides* when a
/// group has enough wall-clock samples).
#[test]
fn empty_measured_overlay_matches_the_model() {
    let empty = PerfLibrary::new(PipelineConfig::default().deep.device.clone());
    let overlay = MeasuredCost::from_library(&empty);
    assert_eq!(overlay.override_count(), 0);

    for (meta, module) in models::all_benchmarks() {
        let mut cfg = PipelineConfig::default();
        cfg.deep.fuse_batch_dot = meta.fuse_batch_dot;
        let mut lib_m = PerfLibrary::new(cfg.deep.device.clone());
        let modeled =
            compile_module(&module, FusionMode::FusionStitching, &mut lib_m, &cfg).unwrap();

        let mut measured_cfg = cfg.clone();
        measured_cfg.cost_source = CostSource::Measured;
        let mut lib_w = PerfLibrary::new(cfg.deep.device.clone());
        let measured =
            compile_module(&module, FusionMode::FusionStitching, &mut lib_w, &measured_cfg)
                .unwrap();

        assert_eq!(
            modeled.plan.digest(),
            measured.plan.digest(),
            "{}: empty overlay changed the plan",
            meta.name
        );
        assert_eq!(modeled.fingerprint, measured.fingerprint, "{}", meta.name);
    }
}

/// Measured feedback that contradicts the model changes the plan: with
/// both resident kernels reported catastrophically slow, the measured
/// re-explore accepts the merge the model refused, and the service
/// swaps the artifact atomically — generation bump, eviction-not-miss.
#[test]
fn measured_overrides_change_the_plan_and_hot_swap() {
    let svc = SharedCompileService::new(PipelineConfig::default());
    let module = swap_module();
    let (base, _) = svc.compile(&module, FusionMode::FusionStitching).unwrap();
    let d0 = base.plan.digest();
    assert!(
        base.plan.generated_kernel_count(&module.entry) >= 2,
        "scenario needs a modeled plan with a rejected merge: {:?}",
        base.plan.generated_kernel_count(&module.entry)
    );
    assert_eq!(svc.cold_compiles(), 1);

    // No feedback yet: the re-explore is a no-op and costs nothing.
    assert!(svc.reexplore_and_swap(&module, FusionMode::FusionStitching).unwrap().is_none());
    assert_eq!(svc.cold_compiles(), 1);

    // Wall-clock write-back: every resident kernel measures 1e9 us.
    let absorbed = svc.absorb_profile(&synthetic_feedback(&base, 1e9));
    assert!(absorbed > 0, "write-back must absorb the synthetic launches");
    assert!(svc.measured_epoch() > 0);

    let before = svc.stats();
    let swapped = svc
        .reexplore_and_swap(&module, FusionMode::FusionStitching)
        .unwrap()
        .expect("contradicting measurements must change the plan");
    assert_ne!(swapped.plan.digest(), d0, "swap requires a strictly changed plan");
    assert!(
        swapped.plan.generated_kernel_count(&module.entry)
            < base.plan.generated_kernel_count(&module.entry),
        "measured re-explore should merge the 'slow' kernels"
    );
    assert_eq!(svc.generation(), 1);
    assert_eq!(svc.cold_compiles(), 2, "exactly one background recompile");

    let after = svc.stats();
    assert_eq!(after.misses, before.misses, "a hot swap is not a lookup failure");
    assert_eq!(after.evictions, before.evictions + 1, "displaced artifact counts as eviction");

    // The resident artifact under the original key IS the new plan.
    let resident = svc.probe(&module, FusionMode::FusionStitching).unwrap();
    assert!(Arc::ptr_eq(&resident, &swapped));

    // Nothing new measured since: the next re-explore converges (the
    // measured plan is already resident, digest unchanged, no swap).
    assert!(svc.reexplore_and_swap(&module, FusionMode::FusionStitching).unwrap().is_none());
    assert_eq!(svc.generation(), 1);
}

/// The live gate: a serving pool under continuous traffic hot-swaps the
/// module mid-serve — every request before, during and after the swap
/// answers successfully, and the final resident plan differs.
#[test]
fn live_pool_hot_swaps_mid_serve_without_dropping_requests() {
    let dir = TempDir::new("autotune-live");
    std::fs::write(dir.path().join("double.hlo.txt"), DOUBLE_HLO).unwrap();

    let module = swap_module();
    let in_elems = 1024 * 256;
    let cfg = ServerConfig {
        artifact: "double".into(),
        batch: 1,
        in_elems_per_request: in_elems,
        out_elems_per_request: 1,
        input_dims: vec![1024, 256],
        policy: BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(2) },
        compile: Some(CompileOptions {
            module: module.clone(),
            mode: FusionMode::FusionStitching,
            pipeline: PipelineConfig::default(),
            use_stitched_backend: true,
            specialize: None,
        }),
        buckets: None,
        trace: None,
        deadline: None,
        faults: None,
    };

    // Pre-warm the shared service so the baseline digest is known, and
    // seed the contradiction before the autotuner's first tick.
    let service = Arc::new(SharedCompileService::new(PipelineConfig::default()));
    let (base, _) = service.compile(&module, FusionMode::FusionStitching).unwrap();
    assert!(
        base.executable.is_some(),
        "stitched serving needs a lowered module: {:?}",
        base.exec_error
    );
    let d0 = base.plan.digest();
    assert!(service.absorb_profile(&synthetic_feedback(&base, 1e9)) > 0);

    // min_launches = MAX: the live write-back path stays armed but
    // never fires, so the synthetic overrides cannot be diluted by real
    // (fast) samples while the test runs.
    let pool = ServingPool::start_with_service(
        dir.path(),
        cfg,
        PoolConfig {
            workers: 2,
            queue_depth: 16,
            autotune: Some(AutotuneConfig {
                interval: Duration::from_millis(5),
                min_launches: u64::MAX,
            }),
            ..PoolConfig::default()
        },
        service.clone(),
    )
    .unwrap();

    // Serve continuously until the swap lands (bounded), then keep
    // serving to prove the new module answers traffic.
    let input = vec![0.25f32; in_elems];
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut served = 0u64;
    while service.generation() == 0 {
        assert!(Instant::now() < deadline, "autotuner never swapped (served {served})");
        let (out, _) = pool.infer_keyed(served, input.clone()).expect("request during swap window");
        assert_eq!(out.len(), 1);
        served += 1;
    }
    for k in 0..8u64 {
        let (out, _) = pool.infer_keyed(1000 + k, input.clone()).expect("request after swap");
        assert_eq!(out.len(), 1);
        served += 1;
    }

    let swapped = service.probe(&module, FusionMode::FusionStitching).unwrap();
    assert_ne!(swapped.plan.digest(), d0, "resident plan must have changed");
    assert!(service.generation() >= 1);

    let stats = pool.shutdown().unwrap();
    assert_eq!(stats.aggregate.requests as u64, served, "every submitted request was served");
    assert_eq!(stats.aggregate.rejected, 0, "no request rejected across the swap");
    assert_eq!(stats.aggregate.compile_failures, 0);
    assert_eq!(stats.generation, Some(service.generation()));
    assert_eq!(
        stats.cold_compiles,
        Some(2),
        "warmup + one background re-explore; serving batches all hit"
    );
}
