//! Runtime integration against the real AOT artifacts, executed by the
//! HLO-text interpreter behind `runtime::Engine` (skipped cleanly when
//! `make artifacts` has not run — lowering the artifacts needs jax,
//! which CI does not carry). `compile: None` below means the serving
//! loop runs without the compile-once cache; the compile-path variants
//! live in `tests/compile_cache.rs`.

use fusion_stitching::coordinator::batcher::BatchPolicy;
use fusion_stitching::coordinator::{ServerConfig, ServingCoordinator};
use fusion_stitching::runtime::Engine;
use std::path::Path;
use std::time::Duration;

const BATCH: usize = 8;
const SEQ: usize = 64;
const MODEL: usize = 512;
const DIM: usize = 64;

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("attention_fused.hlo.txt").exists() {
        Some(p)
    } else {
        eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
        None
    }
}

#[test]
fn engine_loads_all_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(dir).unwrap();
    let stems = engine.load_all().unwrap();
    for want in
        ["attention_fused", "attention_unfused", "layernorm_fused", "layernorm_unfused"]
    {
        assert!(stems.iter().any(|s| s == want), "missing artifact {want}");
    }
}

#[test]
fn fused_and_unfused_attention_agree_numerically() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(dir).unwrap();
    engine.load("attention_fused").unwrap();
    engine.load("attention_unfused").unwrap();
    let input: Vec<f32> =
        (0..BATCH * SEQ * MODEL).map(|i| ((i % 601) as f32 / 601.0) - 0.5).collect();
    let dims = [(BATCH * SEQ) as i64, MODEL as i64];
    let fused = engine.get("attention_fused").unwrap().run_f32(&[(&input, &dims)]).unwrap();
    let unfused =
        engine.get("attention_unfused").unwrap().run_f32(&[(&input, &dims)]).unwrap();
    assert_eq!(fused[0].len(), BATCH * SEQ * DIM);
    let max_diff = fused[0]
        .iter()
        .zip(&unfused[0])
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 1e-3, "stitched kernel diverged: {max_diff}");
    assert!(fused[0].iter().all(|v| v.is_finite()));
}

#[test]
fn layernorm_artifacts_agree() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(dir).unwrap();
    engine.load("layernorm_fused").unwrap();
    engine.load("layernorm_unfused").unwrap();
    let (rows, d) = (256usize, 512usize);
    let input: Vec<f32> = (0..rows * d).map(|i| ((i % 37) as f32) * 0.1).collect();
    let dims = [rows as i64, d as i64];
    let a = engine.get("layernorm_fused").unwrap().run_f32(&[(&input, &dims)]).unwrap();
    let b = engine.get("layernorm_unfused").unwrap().run_f32(&[(&input, &dims)]).unwrap();
    let max_diff =
        a[0].iter().zip(&b[0]).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max);
    assert!(max_diff < 1e-3, "layernorm diverged: {max_diff}");
}

#[test]
fn serving_loop_runs_real_artifact() {
    let Some(dir) = artifacts_dir() else { return };
    let srv = ServingCoordinator::start(
        dir,
        ServerConfig {
            artifact: "attention_fused".into(),
            batch: BATCH,
            in_elems_per_request: SEQ * MODEL,
            out_elems_per_request: SEQ * DIM,
            input_dims: vec![(BATCH * SEQ) as i64, MODEL as i64],
            policy: BatchPolicy { max_batch: BATCH, max_wait: Duration::from_millis(1) },
            compile: None,
            buckets: None,
            trace: None,
            deadline: None,
            faults: None,
        },
    )
    .unwrap();
    let pending: Vec<_> = (0..16)
        .map(|i| srv.infer_async(vec![0.05 * i as f32; SEQ * MODEL]).unwrap())
        .collect();
    for rx in pending {
        let out = rx.recv().unwrap().unwrap();
        assert_eq!(out.len(), SEQ * DIM);
        assert!(out.iter().all(|v| v.is_finite()));
    }
    let stats = srv.shutdown().unwrap();
    assert_eq!(stats.requests, 16);
    assert!(stats.batches <= 16);
}
