//! Flight-recorder integration tests: span-set determinism, bounded
//! rings, and launch-span ↔ `LaunchLedger` reconciliation on both VM
//! paths and through the serving loop.

#![cfg(feature = "trace")]

use fusion_stitching::coordinator::batcher::BatchPolicy;
use fusion_stitching::coordinator::server::CompileOptions;
use fusion_stitching::coordinator::{
    compile_module, CompiledModule, FusionMode, PipelineConfig, ServerConfig, ServingCoordinator,
};
use fusion_stitching::exec::{ExecArena, LaunchLedger};
use fusion_stitching::gpusim::DeviceConfig;
use fusion_stitching::hlo::Module;
use fusion_stitching::models;
use fusion_stitching::obs::{self, SpanCat, TraceConfig, TraceSink};
use fusion_stitching::schedule::PerfLibrary;
use fusion_stitching::testutil::TempDir;
use std::time::Duration;

fn fill(n: usize, seed: u64) -> Vec<f32> {
    (0..n)
        .map(|i| {
            let h = (i as u64).wrapping_mul(2654435761).wrapping_add(seed.wrapping_mul(97));
            ((h % 1000) as f32) / 1000.0 - 0.5
        })
        .collect()
}

fn inputs_for(module: &Module, seed: u64) -> Vec<Vec<f32>> {
    module
        .entry
        .parameters()
        .into_iter()
        .enumerate()
        .map(|(k, id)| {
            let elems = module.entry.get(id).shape.num_elements() as usize;
            fill(elems, seed + k as u64)
        })
        .collect()
}

fn lower(name: &str) -> (Module, CompiledModule) {
    let (meta, module) = models::by_name(name).unwrap();
    let mut lib = PerfLibrary::new(DeviceConfig::pascal());
    let mut cfg = PipelineConfig::default();
    cfg.deep.fuse_batch_dot = meta.fuse_batch_dot;
    let compiled = compile_module(&module, FusionMode::FusionStitching, &mut lib, &cfg).unwrap();
    assert!(compiled.executable.is_some(), "{name} must lower: {:?}", compiled.exec_error);
    (module, compiled)
}

/// Timestamp-free identity of a span: everything the recorder captured
/// except when it happened.
fn span_key(e: &obs::SpanEvent) -> String {
    format!(
        "{:?}|{}|{}|{:016x}|{:?}|{}|{}",
        e.cat, e.name, e.worker, e.fp, e.tier, e.fences, e.barriers
    )
}

fn sorted_span_keys(snap: &obs::TraceSnapshot) -> Vec<String> {
    let mut keys: Vec<String> = snap.events.iter().map(span_key).collect();
    keys.sort();
    keys
}

/// Replay `runs` fast-path executions under a fresh sink at a fixed VM
/// thread count; returns (snapshot, cumulative ledger).
fn replay_fast(
    exe: &fusion_stitching::exec::StitchedExecutable,
    module: &Module,
    threads: usize,
    runs: usize,
) -> (obs::TraceSnapshot, LaunchLedger) {
    let sink = TraceSink::new(TraceConfig::default());
    let _g = obs::install(&sink, threads as u32, None);
    let inputs = inputs_for(module, 42);
    let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
    let mut arena = ExecArena::with_threads(threads);
    let mut out = Vec::new();
    let mut ledger = LaunchLedger::default();
    for _ in 0..runs {
        let run = exe.run_into(&refs, &mut arena, &mut out).unwrap();
        ledger.merge(&run);
    }
    (sink.snapshot(), ledger)
}

#[test]
fn same_model_same_threads_means_identical_span_set() {
    let (module, compiled) = lower("NMT");
    let exe = compiled.executable.as_ref().unwrap();
    let (snap_a, ledger_a) = replay_fast(exe, &module, 2, 3);
    let (snap_b, ledger_b) = replay_fast(exe, &module, 2, 3);
    assert_eq!(ledger_a, ledger_b, "replays must pay identical launches");
    let keys_a = sorted_span_keys(&snap_a);
    assert!(!keys_a.is_empty(), "replay must record launch spans");
    assert_eq!(
        keys_a,
        sorted_span_keys(&snap_b),
        "same model + same thread count must produce the same span set"
    );
}

#[test]
fn ring_overflow_drops_exactly() {
    let sink = TraceSink::new(TraceConfig { enabled: true, capacity_per_worker: 4 });
    let _g = obs::install(&sink, 0, None);
    for _ in 0..10 {
        obs::record(SpanCat::Batch, "assemble", 0, obs::begin());
    }
    let snap = sink.snapshot();
    assert_eq!(snap.events.len(), 4, "ring holds exactly its capacity");
    assert_eq!(snap.dropped, 6, "every overflowed event is counted");
    assert_eq!(sink.dropped_events(), 6);
}

#[test]
fn launch_spans_reconcile_with_ledger_on_both_paths() {
    for name in ["LR", "NMT"] {
        let (module, compiled) = lower(name);
        let exe = compiled.executable.as_ref().unwrap();
        let inputs = inputs_for(&module, 7);

        // Fast path at 1/2/4 VM threads: the tier-tagged launch spans
        // must match the ledger's tier counters exactly.
        for threads in [1usize, 2, 4] {
            let (snap, ledger) = replay_fast(exe, &module, threads, 2);
            let (plain, shm, global) = snap.launch_tier_counts();
            assert_eq!(
                (plain, shm, global),
                (ledger.tier_plain, ledger.tier_shm, ledger.tier_global),
                "{name} fast path @ {threads} threads"
            );
            assert_eq!(
                plain + shm + global,
                ledger.generated,
                "{name}: every generated launch is tier-tagged"
            );
        }

        // Boxed path: same reconciliation, and the same tier split as
        // the fast path (the partition does not depend on the executor).
        let sink = TraceSink::new(TraceConfig::default());
        let boxed_ledger = {
            let _g = obs::install(&sink, 99, None);
            exe.run_boxed(&inputs).unwrap().1
        };
        let snap = sink.snapshot();
        let (plain, shm, global) = snap.launch_tier_counts();
        assert_eq!(
            (plain, shm, global),
            (boxed_ledger.tier_plain, boxed_ledger.tier_shm, boxed_ledger.tier_global),
            "{name} boxed path"
        );
        assert_eq!(plain + shm + global, boxed_ledger.generated);

        let (_, fast_ledger) = replay_fast(exe, &module, 2, 1);
        assert_eq!(
            (fast_ledger.tier_plain, fast_ledger.tier_shm, fast_ledger.tier_global),
            (boxed_ledger.tier_plain, boxed_ledger.tier_shm, boxed_ledger.tier_global),
            "{name}: boxed and fast paths agree on the tier split"
        );
    }
}

#[test]
fn profile_collects_with_sink_disabled() {
    let (module, compiled) = lower("LR");
    let exe = compiled.executable.as_ref().unwrap();
    let sink = TraceSink::new(TraceConfig { enabled: false, capacity_per_worker: 64 });
    let ledger = {
        let _g = obs::install(&sink, 0, Some(compiled.profile.clone()));
        let inputs = inputs_for(&module, 1);
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let mut arena = ExecArena::default();
        let mut out = Vec::new();
        exe.run_into(&refs, &mut arena, &mut out).unwrap()
    };
    assert_eq!(sink.snapshot().events.len(), 0, "disabled sink records no spans");
    let prof = compiled.profile.snapshot();
    assert_eq!(prof.total_launches(), ledger.generated, "profile still measures every launch");
    for (_, g) in prof.groups() {
        assert!(g.measured_us.count() > 0);
        assert!(g.modeled_us > 0.0, "compile-time seeding attaches the modeled cost");
    }
}

#[test]
fn serving_trace_covers_every_category_and_reconciles() {
    use fusion_stitching::hlo::{GraphBuilder, Shape};

    let dir = TempDir::new("obs-serve");
    const DOUBLE_HLO: &str = r#"HloModule double, entry_computation_layout={(f32[4,3]{1,0})->(f32[4,3]{1,0})}

ENTRY main {
  p0 = f32[4,3]{1,0} parameter(0)
  sum = f32[4,3]{1,0} add(p0, p0)
  ROOT t = (f32[4,3]{1,0}) tuple(sum)
}
"#;
    std::fs::write(dir.path().join("double.hlo.txt"), DOUBLE_HLO).unwrap();

    let mut b = GraphBuilder::new("entry");
    let x = b.param("x", Shape::f32(&[4, 3]));
    let e = b.exp(x);
    let t = b.tanh(e);
    let module = Module::new("served", b.finish(t));

    let sink = TraceSink::new(TraceConfig::default());
    let cfg = ServerConfig {
        artifact: "double".into(),
        batch: 4,
        in_elems_per_request: 3,
        out_elems_per_request: 3,
        input_dims: vec![4, 3],
        policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) },
        compile: Some(CompileOptions {
            module,
            mode: FusionMode::FusionStitching,
            pipeline: PipelineConfig::default(),
            use_stitched_backend: true,
            specialize: None,
        }),
        buckets: None,
        trace: Some(sink.clone()),
        deadline: None,
        faults: None,
    };
    let srv = ServingCoordinator::start(dir.path(), cfg).unwrap();
    for i in 0..8 {
        let (out, _) = srv.infer(vec![0.1 * i as f32; 3]).unwrap();
        let want = (0.1f32 * i as f32).exp().tanh();
        assert!((out[0] - want).abs() < 1e-6);
    }
    let stats = srv.shutdown().unwrap();
    let snap = sink.snapshot();

    // The request lifecycle leaves at least one span in every category:
    // queue wait, batch assembly, compile (one cold + hits), the cold
    // compile's pass children, the VM launch, and the reply.
    for cat in SpanCat::ALL {
        assert!(
            snap.count_by_cat(cat) > 0,
            "no {} spans in {} events",
            cat.label(),
            snap.events.len()
        );
    }
    // One queue span per served request; one reply span per batch.
    assert_eq!(snap.count_by_cat(SpanCat::Queue), stats.requests);
    assert_eq!(snap.count_by_cat(SpanCat::Reply), stats.batches);
    // Launch spans reconcile with the ledger's tier counters.
    let (plain, shm, global) = snap.launch_tier_counts();
    assert_eq!(plain + shm + global, stats.launches.generated);
    assert_eq!(
        (plain, shm, global),
        (stats.launches.tier_plain, stats.launches.tier_shm, stats.launches.tier_global)
    );
    // The adopted kernel profile measured the same launches.
    let profile = stats.profile.expect("stitched serving adopts the module profile");
    assert_eq!(profile.snapshot().total_launches(), stats.launches.generated);
    // Nothing overflowed at this traffic volume.
    assert_eq!(snap.dropped, 0);
}
