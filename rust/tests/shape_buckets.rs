//! Integration tests for shape-class bucketing: cache re-keying on the
//! bucket's canonical fingerprint, the bucket policy in the config
//! digest, end-to-end padded serving vs exact-shape serving, the
//! degenerate exact policy's invariance, and the worker-side defense
//! against lying bucket claims.

use fusion_stitching::coordinator::batcher::BatchPolicy;
use fusion_stitching::coordinator::buckets::BucketPolicy;
use fusion_stitching::coordinator::cache::{CacheKey, SharedCompileService};
use fusion_stitching::coordinator::pipeline::{FusionMode, PipelineConfig};
use fusion_stitching::coordinator::pool::{PoolConfig, ServingPool};
use fusion_stitching::coordinator::server::CompileOptions;
use fusion_stitching::coordinator::{ServerConfig, ServingCoordinator};
use fusion_stitching::hlo::{GraphBuilder, Module, Shape};
use fusion_stitching::testutil::TempDir;
use std::sync::Arc;
use std::time::Duration;

const BATCH: usize = 4;

/// Doubles a [4, 3] batch — the interpreter artifact behind every
/// serving loop here (stitched legs never execute it).
const DOUBLE_HLO: &str = r#"HloModule double, entry_computation_layout={(f32[4,3]{1,0})->(f32[4,3]{1,0})}

ENTRY main {
  p0 = f32[4,3]{1,0} parameter(0)
  sum = f32[4,3]{1,0} add(p0, p0)
  ROOT t = (f32[4,3]{1,0}) tuple(sum)
}
"#;

/// The specializer: `tanh(exp(x))` over a `[BATCH, len]` batch. One
/// bucket's canonical module is `chain(canonical_len)`.
fn chain(len: usize) -> Module {
    let mut b = GraphBuilder::new("entry");
    let x = b.param("x", Shape::f32(&[BATCH as i64, len as i64]));
    let e = b.exp(x);
    let t = b.tanh(e);
    Module::new("chain", b.finish(t))
}

#[test]
fn two_shapes_in_one_bucket_pay_one_cold_compile() {
    let policy = BucketPolicy::PowerOfTwo { min: 16 };
    let mut cfg = PipelineConfig::default();
    cfg.bucketing = policy.clone();
    let svc = SharedCompileService::new(cfg);
    // Lengths 17 and 23 both canonicalize to 32: the second request
    // must hit the first's entry, not compile again.
    let (a, hit_a) = svc
        .compile(&chain(policy.canonical_len(17)), FusionMode::FusionStitching)
        .unwrap();
    let (b, hit_b) = svc
        .compile(&chain(policy.canonical_len(23)), FusionMode::FusionStitching)
        .unwrap();
    assert!(!hit_a, "first shape in the bucket compiles cold");
    assert!(hit_b, "second shape in the bucket must hit");
    assert!(Arc::ptr_eq(&a, &b), "bucket members share one artifact");
    assert_eq!(svc.cold_compiles(), 1);
    assert_eq!(svc.cache_len(), 1, "one bucket, one resident entry");
}

#[test]
fn shapes_straddling_a_bucket_boundary_compile_separately() {
    let policy = BucketPolicy::PowerOfTwo { min: 16 };
    assert_eq!(policy.canonical_len(17), 32);
    assert_eq!(policy.canonical_len(40), 64);
    let mut cfg = PipelineConfig::default();
    cfg.bucketing = policy.clone();
    let svc = SharedCompileService::new(cfg);
    let (a, _) = svc
        .compile(&chain(policy.canonical_len(17)), FusionMode::FusionStitching)
        .unwrap();
    let (b, _) = svc
        .compile(&chain(policy.canonical_len(40)), FusionMode::FusionStitching)
        .unwrap();
    assert!(!Arc::ptr_eq(&a, &b));
    assert_eq!(svc.cold_compiles(), 2, "distinct buckets compile independently");
    assert_eq!(svc.cache_len(), 2);
}

#[test]
fn racing_bucket_members_are_single_flight() {
    // Eight threads, eight distinct concrete lengths, one bucket: the
    // shared service must run exactly one cold pipeline.
    let policy = BucketPolicy::PowerOfTwo { min: 16 };
    let mut cfg = PipelineConfig::default();
    cfg.bucketing = policy.clone();
    let svc = Arc::new(SharedCompileService::new(cfg));
    let barrier = Arc::new(std::sync::Barrier::new(8));
    let handles: Vec<_> = (17usize..=24)
        .map(|len| {
            let svc = svc.clone();
            let barrier = barrier.clone();
            let policy = policy.clone();
            std::thread::spawn(move || {
                let m = chain(policy.canonical_len(len));
                barrier.wait();
                svc.compile(&m, FusionMode::FusionStitching).unwrap()
            })
        })
        .collect();
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert_eq!(svc.cold_compiles(), 1, "one bucket, one pipeline run");
    for (artifact, _) in &results[1..] {
        assert!(Arc::ptr_eq(artifact, &results[0].0));
    }
}

#[test]
fn bucket_policy_is_part_of_the_cache_identity() {
    let m = chain(32);
    let exact = PipelineConfig::default();
    let mut bucketed = PipelineConfig::default();
    bucketed.bucketing = BucketPolicy::PowerOfTwo { min: 16 };

    let k_exact = CacheKey::new(&m, FusionMode::FusionStitching, &exact);
    let k_bucketed = CacheKey::new(&m, FusionMode::FusionStitching, &bucketed);
    assert_eq!(
        k_exact.fingerprint, k_bucketed.fingerprint,
        "the module itself is unchanged"
    );
    assert_ne!(
        k_exact.config_digest, k_bucketed.config_digest,
        "changing the bucket policy must change the config digest"
    );
    assert_ne!(k_exact, k_bucketed, "runs bucketing differently never share artifacts");

    // Boundary sets are distinguished too, not just the policy kind.
    let mut coarse = PipelineConfig::default();
    coarse.bucketing = BucketPolicy::Boundaries(vec![32, 128]);
    let mut fine = PipelineConfig::default();
    fine.bucketing = BucketPolicy::Boundaries(vec![32, 64, 128]);
    assert_ne!(
        CacheKey::new(&m, FusionMode::FusionStitching, &coarse).config_digest,
        CacheKey::new(&m, FusionMode::FusionStitching, &fine).config_digest
    );
}

#[test]
fn for_class_collapses_bucket_members_to_one_key() {
    let policy = BucketPolicy::PowerOfTwo { min: 16 };
    let cfg = PipelineConfig::default();
    let spec = Some(chain as fn(usize) -> Module);

    let k17 = CacheKey::for_class(
        &chain(17),
        &policy.class_of(17, 128),
        spec,
        FusionMode::FusionStitching,
        &cfg,
    );
    let k23 = CacheKey::for_class(
        &chain(23),
        &policy.class_of(23, 128),
        spec,
        FusionMode::FusionStitching,
        &cfg,
    );
    assert_eq!(k17, k23, "concrete shapes in one bucket share the canonical key");

    let k40 = CacheKey::for_class(
        &chain(40),
        &policy.class_of(40, 128),
        spec,
        FusionMode::FusionStitching,
        &cfg,
    );
    assert_ne!(k17, k40, "the next bucket is a different key");

    // Without a specializer the class key degenerates to exact-shape
    // keying on the concrete module — bit for bit.
    let degenerate = CacheKey::for_class(
        &chain(17),
        &policy.class_of(17, 128),
        None,
        FusionMode::FusionStitching,
        &cfg,
    );
    assert_eq!(degenerate, CacheKey::new(&chain(17), FusionMode::FusionStitching, &cfg));
}

/// An exact-shape serving loop whose whole contract is one row length —
/// the reference a bucketed loop's live regions are compared against.
fn exact_coordinator(dir: &TempDir, len: usize) -> ServingCoordinator {
    let cfg = ServerConfig {
        artifact: "double".into(),
        batch: BATCH,
        in_elems_per_request: len,
        out_elems_per_request: len,
        input_dims: vec![BATCH as i64, len as i64],
        policy: BatchPolicy { max_batch: BATCH, max_wait: Duration::from_millis(2) },
        compile: Some(CompileOptions {
            module: chain(len),
            mode: FusionMode::FusionStitching,
            pipeline: PipelineConfig::default(),
            use_stitched_backend: true,
            specialize: None,
        }),
        buckets: None,
        trace: None,
        deadline: None,
        faults: None,
    };
    ServingCoordinator::start(dir.path(), cfg).unwrap()
}

#[test]
fn bucketed_serving_matches_exact_shape_serving_bitwise() {
    let dir = TempDir::new("buckets-e2e");
    std::fs::write(dir.path().join("double.hlo.txt"), DOUBLE_HLO).unwrap();

    let policy = BucketPolicy::PowerOfTwo { min: 2 };
    let mut pipeline = PipelineConfig::default();
    pipeline.bucketing = policy.clone();
    let cfg = ServerConfig {
        artifact: "double".into(),
        batch: BATCH,
        in_elems_per_request: 8,
        out_elems_per_request: 8,
        input_dims: vec![BATCH as i64, 8],
        policy: BatchPolicy { max_batch: BATCH, max_wait: Duration::from_millis(2) },
        compile: Some(CompileOptions {
            module: chain(8),
            mode: FusionMode::FusionStitching,
            pipeline,
            use_stitched_backend: true,
            specialize: Some(chain as fn(usize) -> Module),
        }),
        buckets: Some(policy),
        trace: None,
        deadline: None,
        faults: None,
    };
    let bucketed = ServingCoordinator::start(dir.path(), cfg).unwrap();

    for len in [3usize, 4, 6, 8, 2] {
        let input: Vec<f32> = (0..len).map(|i| 0.3 * i as f32 - 0.7).collect();
        let (got, _) = bucketed.infer(input.clone()).unwrap();
        assert_eq!(got.len(), len, "live region only");

        let exact = exact_coordinator(&dir, len);
        let (want, _) = exact.infer(input).unwrap();
        exact.shutdown().unwrap();

        assert_eq!(
            got.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
            "length-{len} live region must match exact-shape serving bit for bit"
        );
    }

    let stats = bucketed.shutdown().unwrap();
    assert_eq!(stats.requests, 5);
    assert_eq!(stats.stitched_batches, stats.batches, "every batch ran a bucket artifact");
    // Lengths {3,4} → bucket 4, {6,8} → bucket 8, {2} → bucket 2:
    // three canonical artifacts serve five concrete shapes.
    assert_eq!(stats.cache_misses, 3, "one cold compile per bucket");
    assert_eq!(stats.cache_hits, 2);
    // Padding actually happened (3→4, 6→8) and is accounted for.
    assert_eq!(stats.padded_elems, 1 + 2);
    assert_eq!(stats.live_elems, (3 + 4 + 6 + 8 + 2) as u64);
    let waste = stats.padding_waste_ratio();
    assert!(waste > 0.0 && waste < 0.5, "waste = {waste}");
}

#[test]
fn degenerate_exact_policy_serves_identically_to_unbucketed() {
    // `Some(BucketPolicy::Exact)` must be indistinguishable from `None`
    // for contract-length traffic: same outputs (bitwise), same batch
    // and cache accounting, zero padding.
    let dir = TempDir::new("buckets-degenerate");
    std::fs::write(dir.path().join("double.hlo.txt"), DOUBLE_HLO).unwrap();
    let base = ServerConfig {
        artifact: "double".into(),
        batch: BATCH,
        in_elems_per_request: 3,
        out_elems_per_request: 3,
        input_dims: vec![BATCH as i64, 3],
        policy: BatchPolicy { max_batch: BATCH, max_wait: Duration::from_millis(2) },
        compile: None,
        buckets: None,
        trace: None,
        deadline: None,
        faults: None,
    };
    let mut exact_bucketed = base.clone();
    exact_bucketed.buckets = Some(BucketPolicy::Exact);

    let mut outputs: Vec<Vec<Vec<u32>>> = Vec::new();
    let mut counters = Vec::new();
    for cfg in [base, exact_bucketed] {
        let srv = ServingCoordinator::start(dir.path(), cfg).unwrap();
        let mut leg = Vec::new();
        for i in 0..6 {
            let (out, _) = srv.infer(vec![0.25 * i as f32, -1.5, 2.0]).unwrap();
            leg.push(out.iter().map(|f| f.to_bits()).collect());
        }
        let stats = srv.shutdown().unwrap();
        counters.push((stats.requests, stats.rejected, stats.padded_elems));
        outputs.push(leg);
    }
    assert_eq!(outputs[0], outputs[1], "degenerate policy must not change outputs");
    assert_eq!(counters[0], (6, 0, 0));
    assert_eq!(counters[1], (6, 0, 0), "exact bucketing pads nothing");
}

#[test]
fn lying_bucket_claims_are_rejected_poolwide() {
    // A row longer than its claimed bucket's canonical length must be
    // rejected with a bucket-naming error and counted, not padded into
    // a batch it cannot fit (which would corrupt its neighbors).
    let dir = TempDir::new("buckets-lie");
    std::fs::write(dir.path().join("double.hlo.txt"), DOUBLE_HLO).unwrap();
    let cfg = ServerConfig {
        artifact: "double".into(),
        batch: BATCH,
        in_elems_per_request: 3,
        out_elems_per_request: 3,
        input_dims: vec![BATCH as i64, 3],
        policy: BatchPolicy { max_batch: BATCH, max_wait: Duration::from_millis(2) },
        compile: None,
        buckets: Some(BucketPolicy::PowerOfTwo { min: 2 }),
        trace: None,
        deadline: None,
        faults: None,
    };
    let p = ServingPool::start(dir.path(), cfg, PoolConfig { workers: 2, ..PoolConfig::default() })
        .unwrap();

    // Legitimate traffic: a contract-length row routes by bucket key
    // and is served in full.
    let (out, _) = p.infer(vec![1.0, 2.0, 3.0]).unwrap();
    assert_eq!(out, vec![2.0, 4.0, 6.0]);

    // A short row is padded to the contract stride for the interpreter
    // and sliced back to its live region.
    let (out, _) = p.infer(vec![0.5, -0.5]).unwrap();
    assert_eq!(out, vec![1.0, -1.0]);

    // The lie: claiming bucket 2 (canonical length 2) with 3 elements.
    let bad = p.infer_keyed(2, vec![0.0; 3]);
    let msg = format!("{:#}", bad.expect_err("oversized row for its claimed bucket"));
    assert!(msg.contains("bucket"), "error must name the claimed bucket: {msg}");
    assert!(msg.contains("3 elements"), "error must name the offending row: {msg}");

    let stats = p.shutdown().unwrap();
    assert_eq!(stats.aggregate.rejected, 1);
    assert_eq!(stats.aggregate.requests, 2, "the lie never reached execution");
    // The len-2 row padded one element up to the contract stride.
    assert_eq!(stats.aggregate.padded_elems, 1);
    assert_eq!(stats.aggregate.live_elems, 5);
    let waste = stats.aggregate.padding_waste_ratio();
    assert!(waste > 0.0 && waste < 0.5, "waste = {waste}");
}
